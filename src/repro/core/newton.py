"""OverSketched Newton (paper Alg. 3 / Alg. 4): the master loop.

Master-side Python loop (the paper's T is in the tens) dispatching jitted
distributed phases:

  1. gradient  — exact, straggler-resilient via the 2-D product code (Alg. 1)
  2. Hessian   — approximate, straggler-resilient via a block-structured
     sketch (Alg. 2).  The family is pluggable (``NewtonConfig.sketch_family``
     resolves through ``repro.sketching``): the paper's OverSketch plus SRHT,
     SJLT, Gaussian and Nystrom row-sampling, all sharing the k-of-n
     survivor semantics because every family is per-block unbiased.
  3. direction — Cholesky/CG (strongly convex) or pinv/MINRES (weakly
     convex), optionally Marchenko-Pastur debiased (``debias=True``,
     Romanov-Zhang-Pilanci 2024); ``sketch_mode="distributed-avg"`` instead
     averages per-worker debiased directions (Bartan-Pilanci 2020).
  4. step size — distributed Armijo (Eq. 5) / grad-norm (Eq. 6) line search

Each distributed phase is scored by the straggler simulation clock
(`core.straggler`), which is how the paper's wall-clock comparisons are
reproduced on a single-device container.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from functools import partial
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

import numpy as np

from repro.core import coded, linesearch, sketch, solvers, straggler
from repro.core.objectives import Dataset
from repro import obs, scheduler, sketching
from repro.runtime.faults import PhaseExhaustedError


def _telemetry(clock) -> "obs.Telemetry":
    """The clock's attached telemetry, or the zero-overhead no-op."""
    return clock.telemetry if clock is not None else obs.NULL


def _decodable(erased_grid: "np.ndarray") -> bool:
    """Host-side peeling feasibility check on the (g+1)x(g+1) erasure grid.
    Mirrors coded.peel_decode: a line with exactly one missing cell can be
    recovered; iterate to fixpoint."""
    known = ~erased_grid.copy()
    g1 = known.shape[0]
    for _ in range(2 * g1):
        if known.all():
            return True
        progress = False
        for axis in (0, 1):
            missing = (~known).sum(axis=axis)
            for i in np.where(missing == 1)[0]:
                if axis == 0:
                    j = int(np.argmin(known[:, i]))
                    known[j, i] = True
                else:
                    j = int(np.argmin(known[i, :]))
                    known[i, j] = True
                progress = True
        if not progress:
            return False
    return bool(known.all())


@dataclasses.dataclass(frozen=True)
class NewtonConfig:
    iters: int = 20
    sketch: sketch.OverSketchConfig = dataclasses.field(
        default_factory=lambda: sketch.OverSketchConfig(
            sketch_dim=2048, block_size=256, straggler_tolerance=0.25))
    beta: float = 0.1
    candidates: tuple = linesearch.DEFAULT_CANDIDATES
    unit_step: bool = False
    solver: str = "auto"            # auto | chol | cg | pinv | minres
    cg_iters: int = 64
    gradient_policy: str = "coded"  # coded | wait_all | ignore | speculative
    hessian_policy: str = "oversketch"   # oversketch | exact | exact_speculative
    # Sketch family registry key: oversketch | srht | sjlt | gaussian | nystrom
    sketch_family: str = "oversketch"
    # Marchenko-Pastur inverse-bias correction of the sketched direction.
    debias: bool = False
    # blocks: one sketch, blocks pooled into a single Gram (paper Alg. 2).
    # distributed-avg: each surviving block-worker solves its own d x d
    # system and the master averages (debiased) directions — needs
    # block_size > d to be well-posed.
    sketch_mode: str = "blocks"
    # distributed-avg per-block d x d solver: chol (dense Cholesky) | cg
    # (matvec-only conjugate gradient, cg_iters steps — for d beyond
    # master-factorization scale).
    distavg_solver: str = "chol"
    coded_block_rows: int = 256
    # Master-side pipeline overlap (Sec. 4.1): the one-time product-code
    # encodes launch together and hide behind earlier compute phases.
    overlap_encode: bool = True
    # Phase dispatch: "dag" emits each iteration as a phase DAG through
    # repro.scheduler — the Hessian-sketch fan-out launches concurrently
    # with the gradient round (they are independent within an iteration;
    # Sec. 4.1 / Bartan-Pilanci's concurrent sketch dispatch) — while
    # "sequential" keeps the historical one-phase-at-a-time clock.  The
    # iterates are identical either way (same phase keys => same masks);
    # only the simulated timeline differs.
    schedule: str = "dag"
    # Per-phase Lambda sizing: declare each phase's working set so it bills
    # at its own memory_gb (scheduler.sizing) instead of the paper's
    # fleet-wide 3 GB.  Off by default to keep historical dollar totals.
    phase_memory: bool = False
    seed: int = 0
    use_kernels: bool = False       # route sketch through repro.kernels ops
    track_test_error: bool = False
    # Paper Thm 3.2 remark: "the sketch dimension can be increased to reduce
    # eps ... and improve the convergence rate in practice" — when iteration
    # progress stalls (the eps-linear tail), double the sketch dimension.
    adaptive_sketch: bool = False
    adaptive_stall_ratio: float = 0.25   # f-decrease ratio that counts as a stall
    adaptive_max_growth: int = 4         # cap: sketch_dim <= 4x initial
    # What drives adaptive growth: "stall" = the f-decrease heuristic above;
    # "mp" = the measured Marchenko-Pastur debias factor 1 - d/m_eff of the
    # SURVIVING sketch rows — grow whenever it falls below
    # adaptive_mp_target, i.e. the sketch is too biased to trust, whether
    # or not f has stalled yet (ROADMAP: the MP factor says *when*).
    adaptive_metric: str = "stall"
    adaptive_mp_target: float = 0.75
    # Graceful degradation under a fault plan (repro.runtime.faults) whose
    # retry budget genuinely exhausts (FleetConfig.fail_open=False).
    # "degrade": accept the surviving sketch blocks when at least
    # survivor_floor of num_blocks landed; below the floor, re-dispatch
    # the sketch round once on fresh capacity; if that exhausts too, take
    # a plain gradient step for the iteration.  "raise": propagate
    # PhaseExhaustedError to the caller (strict mode).
    fault_fallback: str = "degrade"
    survivor_floor: float = 0.5
    # Parity-check detection of corrupted coded-matvec products (fault
    # plan CorruptionSpec): detected cells are demoted to erasures and
    # flow through the existing peeling decoder; off = trust arrived
    # bytes (the silent-corruption negative control).
    corruption_detection: bool = True


@dataclasses.dataclass
class NewtonResult:
    w: jax.Array
    history: Dict[str, List[float]]


def _phase_mem(enabled: bool, working_set_bytes: float) -> Optional[float]:
    """Declared Lambda size for a phase, or None for the fleet-wide 3 GB."""
    return scheduler.lambda_memory_gb(working_set_bytes) if enabled else None


def _ws_gb(working_set_bytes: float) -> float:
    """True per-worker working set in GB, always declared to the engine
    (``working_set_gb``) — unlike the billed ``memory_gb``, which stays
    opt-in via ``phase_memory``.  Inert unless a fault plan with an
    ``OomSpec`` is attached: an undersized Lambda then OOM-kills instead
    of merely billing cheap."""
    return float(working_set_bytes) / 2.0 ** 30


class CodedMatvecEngine:
    """Holds the one-time 2-D product-code encodings of X and X^T (the paper
    amortizes encoding across iterations, Sec. 4.1) and serves straggler-
    resilient matvecs.

    Each operand's encode is billed as a real fleet phase on first use.
    With ``overlap_encode`` (the default, the paper's pipeline) both
    encodes are kicked off when the engine comes up and run concurrently
    with any compute dispatched since — the X^T encode hides behind the
    X matvec via ``run_phase(not_before=...)``; ``overlap_encode=False``
    serializes them (the makespan upper bound)."""

    def __init__(self, data: Dataset, block_rows: int,
                 model: Optional[straggler.StragglerModel],
                 overlap_encode: bool = True, phase_memory: bool = False,
                 corruption_detection: bool = True):
        self.model = model
        self.overlap_encode = overlap_encode
        self.phase_memory = phase_memory
        self.corruption_detection = corruption_detection
        self._encode_pending = {"X", "XT"}
        self._encode_t0: Optional[float] = None
        n, d = data.x.shape
        br_n = max(1, min(block_rows, n))
        br_d = max(1, min(block_rows, d))
        self.code_x = coded.make_code(n, br_n)      # for X @ v    (n rows)
        self.code_xt = coded.make_code(d, br_d)     # for X^T @ v  (d rows)
        self.enc_x = coded.encode_2d(data.x, self.code_x)
        self.enc_xt = coded.encode_2d(data.x.T, self.code_xt)
        self.out_rows = {"X": n, "XT": d}
        self.fallbacks = 0
        # Degraded-mode latch: flips on the first *observed* corruption
        # (a parity flag or a codeword-verification reject).  From then
        # on coded phases wait for FULL arrival instead of the first
        # peelable subset — with every cell present, row x column parity
        # intersection localizes corruption exactly and the verification
        # backstop catches sign-cancellation pathologies, so every later
        # matvec is either exact or a billed relaunch, never silently
        # wrong.  (Racing ahead of stragglers is what lets corruption be
        # absorbed into peel-recovered cells undetectably.)
        self.paranoid = False

        @partial(jax.jit, static_argnames=("tag",))
        def _mv(tag, v, erased):
            enc = self.enc_x if tag == "X" else self.enc_xt
            code = self.code_x if tag == "X" else self.code_xt
            return coded.coded_matvec(enc, v, code, self.out_rows[tag], erased)

        self._mv = _mv

    def code_for(self, tag: str) -> coded.ProductCode:
        return self.code_x if tag == "X" else self.code_xt

    def matvec(self, tag: str, v: jax.Array, clock: straggler.SimClock,
               key: jax.Array, policy: str,
               dag: Optional[scheduler.DagRun] = None,
               name: Optional[str] = None,
               after: Tuple[str, ...] = ()) -> jax.Array:
        """One straggler-resilient coded matvec.

        With ``dag`` the compute phase (and, on decode failure, the retry
        phase) is dispatched as a named DAG node with deps ``after`` —
        the matvec chain inside one gradient stays serialized through
        those edges while independent phases (the Hessian sketch) overlap
        it.  The one-time encode phases keep their own clock-level
        ``not_before`` overlap machinery either way."""
        code = self.code_for(tag)
        w = code.num_workers
        enc = self.enc_x if tag == "X" else self.enc_xt
        flops = 2.0 * code.block_rows * enc.shape[-1]   # one block matvec
        mem_bytes = scheduler.matvec_worker_bytes(code.block_rows,
                                                  enc.shape[-1])
        mem = _phase_mem(self.phase_memory, mem_bytes)
        ws = _ws_gb(mem_bytes)
        enc_floor = {"t": None}   # set if this call bills an encode phase

        def phase(k, policy, *, kk=None, decodable=None, comm_units=1.0):
            if dag is not None:
                # The compute phase consumes this operand's encode: when
                # the encode was billed in this call (on the direct clock,
                # outside the DAG), floor the launch at its finish so the
                # matvec cannot be simulated before its input exists.
                res = dag.dispatch(scheduler.PhaseSpec(
                    name=name or tag, workers=w, policy=policy,
                    k=kk, flops_per_worker=flops, comm_units=comm_units,
                    memory_gb=mem, working_set_gb=ws, decodable=decodable,
                    deps=after), key=k, min_start=enc_floor["t"])
                return res.elapsed, res.mask
            return clock.phase(k, w, policy=policy, k=kk,
                               flops_per_worker=flops,
                               comm_units=comm_units, decodable=decodable,
                               memory_gb=mem, working_set_gb=ws,
                               phase_name=name or tag)

        def phase_safe(k, policy, **kw):
            # A fault plan with a real retry budget (fail_open=False) can
            # exhaust mid-phase: the attempts are already billed and the
            # clock advanced; degrade to whatever arrived — the coded
            # path treats the dead workers as erasures.
            try:
                return phase(k, policy, **kw)
            except PhaseExhaustedError as e:
                _telemetry(clock).metrics.counter(
                    "coded.exhausted_phases").inc()
                return e.elapsed, jnp.asarray(e.mask)
        if self.model is not None and tag in self._encode_pending:
            # One-time product-code encode of this operand, billed on
            # first use.  Both encodes launch when the engine comes up
            # (first matvec's clock time); the overlapped variant lets
            # the later operand's encode hide behind earlier compute
            # (Sec. 4.1), the sequential one pays it in full.
            self._encode_pending.discard(tag)
            if self._encode_t0 is None:
                self._encode_t0 = clock.time
            enc_flops = float(code.block_rows * enc.shape[-1])  # parity adds
            nb = self._encode_t0 if self.overlap_encode else None
            if nb is not None and nb == clock.time:
                # Launching "now" overlaps nothing: take the sequential
                # path so the clock stays bit-identical to it (the
                # engine's advance=elapsed shortcut, no ULP re-rounding).
                nb = None
            try:
                clock.phase(jax.random.fold_in(key, 555), w,
                            policy="wait_all", flops_per_worker=enc_flops,
                            comm_units=1.0, not_before=nb, memory_gb=mem,
                            working_set_gb=ws, phase_name=f"encode:{tag}")
            except PhaseExhaustedError:
                # Encode attempts billed, budget gone: the master re-runs
                # the cheap parity sums locally; the operand is still
                # usable, so only the wasted round is lost.
                _telemetry(clock).metrics.counter(
                    "coded.exhausted_phases").inc()
            # After this call the clock sits at (at least) the encode's
            # finish — the earliest instant this operand can be consumed.
            enc_floor["t"] = clock.time
        erased = None
        corrupt = None
        arrived = None
        if self.model is not None and policy == "coded":
            # Faithful master: results stream in; decode starts as soon as
            # the arrived set is peelable (paper Alg. 1 step 8).  The
            # streaming wait runs through the fleet engine's coded_decode
            # policy with the peeling-feasibility predicate.
            g1 = code.grid + 1
            if self.paranoid and self.corruption_detection:
                _, mask = phase_safe(key, "wait_all")
            else:
                k_min = max(1, w - (2 * code.grid + 1))
                _, mask = phase_safe(key, "coded_decode", kk=k_min,
                                     decodable=lambda m: _decodable(
                                         ~m.reshape(g1, g1)))
            arrived = np.asarray(mask)
            erased = jnp.asarray(~arrived).reshape(g1, g1)
            lc = clock.last_corruption
            if lc is not None:
                # The fault plane flagged some arrived results as
                # corrupted (bit flips / stale S3 reads).  Report the
                # per-phase block error rate even when zero — the health
                # monitors need the clean baseline to detect the shift.
                corrupt = np.asarray(lc) & arrived
                tel = _telemetry(clock)
                if tel.enabled:
                    tel.metrics.gauge("coded.block_error_rate").set(
                        float(corrupt.sum()) / float(w))
        elif self.model is not None and policy == "wait_all":
            phase_safe(key, "wait_all")
        elif self.model is not None and policy == "speculative":
            phase_safe(key, "speculative")
        elif self.model is not None and policy == "ignore":
            # mini-batch style: drop stragglers' contributions entirely —
            # handled by the caller using an uncoded gradient; we still pay
            # the k-of-n time.
            phase_safe(key, "k_of_n", kk=max(1, int(0.95 * w)))
        if corrupt is not None and corrupt.any():
            # Reconstruct what the master actually received: clean block
            # products plus seeded garbage at the corrupted cells.
            g1 = code.grid + 1
            prods = coded.coded_block_products(enc, v)
            noise = (jnp.sqrt(jnp.mean(prods ** 2)) + 1e-30) * \
                jax.random.normal(jax.random.fold_in(key, 777), prods.shape)
            cgrid = jnp.asarray(corrupt.reshape(g1, g1))
            prods = jnp.where(cgrid[..., None], prods + noise, prods)
            known = jnp.asarray(arrived.reshape(g1, g1))
            tel = _telemetry(clock)
            if tel.enabled:
                tel.metrics.counter("coded.corruption_injected").inc(
                    int(corrupt.sum()))
            if self.corruption_detection:
                # Parity checks demote localizable corruption to erasures;
                # the post-decode codeword verification rejects anything
                # that slipped through (ok=False -> billed full relaunch
                # below) instead of returning a silently wrong product.
                y, ok, n_flagged = coded.verified_decode(
                    prods, known, code, self.out_rows[tag])
                if tel.enabled and n_flagged:
                    tel.metrics.counter("coded.corruption_detected").inc(
                        n_flagged)
                if (n_flagged or not bool(ok)) and not self.paranoid:
                    self.paranoid = True
                    if tel.enabled:
                        tel.metrics.counter("coded.paranoid_mode").inc()
                if y is None:
                    y = jnp.zeros((self.out_rows[tag],), prods.dtype)
            else:
                y, ok = coded.decode_matvec(prods, known, code,
                                            self.out_rows[tag])
        else:
            y, ok = self._mv(tag, v, erased)
        if erased is not None and not bool(ok):
            # Decode failure (erasure pattern beyond the code): the paper's
            # master re-launches stragglers; charge a full re-execution round.
            self.fallbacks += 1
            y, _ = self._mv(tag, v, None)
            if self.model is not None:
                _telemetry(clock).metrics.counter(
                    "coded.decode_fallbacks").inc()
                kf = jax.random.fold_in(key, 1)
                try:
                    # An exhausted compute phase never registered with the
                    # DAG, so only declare the edge when the dep exists;
                    # otherwise the barrier at the current clock stands in.
                    if dag is not None and (name or tag) in dag.results:
                        dag.dispatch(scheduler.PhaseSpec(
                            name=(name or tag) + "/retry", workers=w,
                            policy="wait_all", comm_units=1.0,
                            memory_gb=mem, working_set_gb=ws,
                            deps=((name or tag),)), key=kf)
                    else:
                        clock.phase(kf, w, policy="wait_all",
                                    comm_units=1.0, memory_gb=mem,
                                    working_set_gb=ws,
                                    phase_name=(name or tag) + "/retry")
                except PhaseExhaustedError:
                    # The relaunch round itself exhausted: its attempts
                    # are billed, the master already recomputed y above.
                    _telemetry(clock).metrics.counter(
                        "coded.exhausted_phases").inc()
        return y


def _solve_direction(objective, h_hat: jax.Array, g: jax.Array,
                     cfg: NewtonConfig) -> jax.Array:
    solver = cfg.solver
    if solver == "auto":
        solver = "chol" if objective.strongly_convex else "pinv"
    if solver == "chol":
        return -solvers.psd_solve(h_hat, g)
    if solver == "cg":
        return -solvers.conjugate_gradient(lambda v: h_hat @ v, g,
                                           jnp.zeros_like(g), cfg.cg_iters)
    if solver == "pinv":
        return -solvers.psd_pinv_solve(h_hat, g)
    if solver == "minres":
        return -solvers.minres(lambda v: h_hat @ v, g, cfg.cg_iters)
    raise ValueError(solver)


@functools.lru_cache(maxsize=64)
def _jitted_sketched_hessian(objective, family: "sketching.SketchFamily",
                             use_kernels: bool):
    """Hashable frozen-dataclass objectives AND families => cacheable
    jitted closures.  ``state`` is the family's sketch realization pytree.

    With ``use_kernels`` the Hessian build prefers the family's fused
    streaming sketch->Gram kernel (``SketchFamily.gram_fused``: one pass
    over hess_sqrt rows, A_tilde never materialized in HBM).  The kernel
    d-tiles its output grid, so oversketch/srht/sjlt take the fused path
    for EVERY d (``SketchFamily.fused_path(d)`` reports "fused" vs
    "fused_tiled"); families without an encode-matrix form fall back to
    the two-kernel apply+gram chain ("unfused").

    The path actually taken is logged as a telemetry metric
    (``kernel.path.<fused|fused_tiled|unfused>``) at this function's call
    site in ``_hessian_phase`` — inside the jitted closure there is no
    Python left to log from — so production path selection is auditable
    against the ``BENCH_kernels.json`` per-row ``path`` field."""
    def fn(w, data, state, survivors):
        a = objective.hess_sqrt(w, data)
        d = a.shape[1]
        reg = objective.hess_reg * jnp.eye(d, dtype=a.dtype)
        return family.gram(state, a, survivors, use_kernels=use_kernels) + reg
    return jax.jit(fn)


@functools.lru_cache(maxsize=64)
def _jitted_distavg_direction(objective, family: "sketching.SketchFamily",
                              debias: bool, use_kernels: bool,
                              solver: str = "chol", cg_iters: int = 64):
    """distributed-avg mode (Bartan-Pilanci 2020): every surviving block-
    worker solves its own per-block sketched system, the master averages
    the (Marchenko-Pastur debiased) directions.  Per-worker sketch rows =
    block_size, so the debias factor is 1 - d/b.  Also returns the masked
    average of H_k g for the weakly-convex line search.  ``solver`` picks
    the per-block d x d solve: dense Cholesky, or matvec-only CG for d
    beyond master-factorization scale."""
    b = family.cfg.block_size

    if solver == "cg":
        def block_solve(hk, g):
            return solvers.conjugate_gradient(
                lambda v: hk @ v, g, jnp.zeros_like(g), cg_iters)
    elif solver == "chol":
        block_solve = solvers.psd_solve
    else:
        raise ValueError(f"unknown distavg_solver {solver!r}")

    def fn(w, data, g, state, survivors):
        a = objective.hess_sqrt(w, data)
        d = a.shape[1]
        a_t = family.apply(state, a, use_kernels=use_kernels)  # (K, b, d)
        eye = jnp.eye(d, dtype=a_t.dtype)
        grams = jnp.einsum("kbd,kbe->kde", a_t, a_t) \
            + objective.hess_reg * eye
        p_k = -jax.vmap(lambda hk: block_solve(hk, g))(grams)
        if debias:
            p_k = sketching.debias_direction(p_k, d, b)
        m = survivors.astype(a_t.dtype)
        n_avail = jnp.maximum(m.sum(), 1.0)
        p = jnp.einsum("k,kd->d", m, p_k) / n_avail
        hg = jnp.einsum("k,kde,e->d", m, grams, g) / n_avail
        return p, hg
    return jax.jit(fn)


@functools.lru_cache(maxsize=64)
def _jitted_exact_hessian(objective):
    def fn(w, data):
        a = objective.hess_sqrt(w, data)
        d = a.shape[1]
        return a.T @ a + objective.hess_reg * jnp.eye(d, dtype=a.dtype)
    return jax.jit(fn)


def _hess_rows(objective, data: Dataset, w: jax.Array) -> Tuple[int, int]:
    shape = jax.eval_shape(objective.hess_sqrt, w, data).shape
    return shape[0], shape[1]


def _hessian_phase(objective, data: Dataset, w: jax.Array, cfg: NewtonConfig,
                   key: jax.Array, clock: Optional[straggler.SimClock],
                   dag: Optional[scheduler.DagRun] = None,
                   tag: str = "hessian"
                   ) -> Tuple[jax.Array, Optional[float]]:
    """Returns (H_hat, m_eff): the (approximate or exact) Hessian including
    the hess_reg * I term, and the surviving sketch-row count m_eff that the
    Marchenko-Pastur debias factor needs (None on the exact path).
    Under a fault plan with ``fail_open=False`` and
    ``cfg.fault_fallback="degrade"``, ``(None, None)`` means the sketch
    round (and its one re-dispatch) lost too many blocks to trust — the
    caller takes a plain gradient step for the iteration.

    Worker accounting follows the paper: a sketched Hessian invokes
    (N+e)*(d/b)^2 workers (Alg. 2 step 3) vs ceil(n/b)*(d/b)^2 for the exact
    product — same per-worker block work, vastly different worker counts and
    master I/O when n >> m.  Per-worker flops and I/O come from the family's
    cost hooks, so e.g. dense Gaussian pays its O(n*b*d) apply honestly.

    With ``dag`` the phase is dispatched as a dependency-free DAG node — it
    launches at the iteration start, concurrent with the gradient round
    (the sketch S^T A depends on w only, not on g).  The phase key is the
    same either way, so the survivor mask (hence the iterate) is identical
    under both schedules."""
    n_rows, d = _hess_rows(objective, data, w)
    b = max(cfg.sketch.block_size, 1)
    d_blocks = max(1, -(-d // b))

    def run(workers, policy, k=None, flops=0.0, comm=0.0, mem=None,
            ws=None, name=None, rkey=None, min_start=None):
        name = tag if name is None else name
        rkey = key if rkey is None else rkey
        if dag is not None:
            return dag.dispatch(scheduler.PhaseSpec(
                name=name, workers=workers, policy=policy, k=k,
                flops_per_worker=flops, comm_units=comm,
                memory_gb=mem, working_set_gb=ws), key=rkey,
                min_start=min_start).mask
        _, mask = clock.phase(rkey, workers, policy=policy, k=k,
                              flops_per_worker=flops, comm_units=comm,
                              memory_gb=mem, working_set_gb=ws,
                              phase_name=name)
        return mask

    if cfg.hessian_policy == "oversketch":
        scfg = cfg.sketch
        fam = sketching.get(cfg.sketch_family, scfg)
        survivors = jnp.ones((scfg.total_blocks,), bool)
        if clock is not None:
            # Alg. 2 termination is per OUTPUT TILE: each of the (d/b)^2
            # tiles waits for any N of its N+e sketch-block workers.  The
            # tile groups run in parallel (phase time ~ one k-of-n round);
            # the master I/O scales with the full worker count.
            total_workers = scfg.total_blocks * d_blocks * d_blocks
            mem_bytes = scheduler.sketch_worker_bytes(scfg.block_size,
                                                      min(d, b))
            kw = dict(k=scfg.num_blocks, flops=fam.block_flops(n_rows, d),
                      comm=fam.comm_units(d) * total_workers,
                      mem=_phase_mem(cfg.phase_memory, mem_bytes),
                      ws=_ws_gb(mem_bytes))
            try:
                survivors = run(scfg.total_blocks, "k_of_n", **kw)
            except PhaseExhaustedError as e:
                if cfg.fault_fallback == "raise":
                    raise
                # The sketch round exhausted its retry budget (attempts
                # billed, clock advanced).  Every sketch block is
                # per-block unbiased, so any survivor subset is still an
                # unbiased (thinner) sketch: accept the survivors when at
                # least survivor_floor of num_blocks landed — m_eff
                # shrinks and the MP debias absorbs the extra bias.
                # Below the floor, re-dispatch the round once on fresh
                # capacity; if that exhausts too, signal the caller to
                # take a plain gradient step this iteration.
                _telemetry(clock).metrics.counter(
                    "newton.fault_fallbacks").inc()
                floor = max(1, math.ceil(
                    cfg.survivor_floor * scfg.num_blocks))
                surv = np.asarray(e.mask)
                if int(surv.sum()) >= floor:
                    survivors = jnp.asarray(surv)
                else:
                    try:
                        survivors = run(
                            scfg.total_blocks, "k_of_n",
                            name=tag + "/retry",
                            rkey=jax.random.fold_in(key, 13),
                            min_start=float(clock.time), **kw)
                    except PhaseExhaustedError as e2:
                        surv2 = np.asarray(e2.mask)
                        if int(surv2.sum()) < floor:
                            return None, None
                        survivors = jnp.asarray(surv2)
        state = fam.sample(jax.random.fold_in(key, 7), n_rows)
        tel = _telemetry(clock)
        if tel.enabled:
            # Audit trail for kernel auto-routing: the path the fused
            # sketch->Gram dispatch ACTUALLY takes for this (family, d),
            # comparable against BENCH_kernels.json rows instead of
            # assumed from the config.
            path = fam.fused_path(d) if cfg.use_kernels else "unfused"
            tel.metrics.counter(f"kernel.path.{path}").inc()
        fn = _jitted_sketched_hessian(objective, fam, cfg.use_kernels)
        h_hat = fn(w, data, state, survivors)
        m_eff = float(jnp.sum(survivors)) * scfg.block_size
        if tel.enabled:
            tel.metrics.gauge("sketch.m_eff").set(m_eff)
            tel.metrics.gauge("sketch.mp_debias").set(
                max(0.0, 1.0 - d / m_eff) if m_eff > 0 else 0.0)
            # Survivor count per sketch round: the straggler-aware
            # provisioning statistic the launch planner reads back out of
            # the cross-run store (obs.store run records keep the full
            # per-round series).
            tel.metrics.histogram("sketch.survivors").observe(
                float(jnp.sum(survivors)))
        return h_hat, m_eff
    # exact Hessian (paper's "exact Newton" baseline)
    block_flops = 2.0 * b * min(d, b) ** 2    # one (b x d_tile) gram block
    if clock is not None:
        workers = max(1, -(-n_rows // b)) * d_blocks * d_blocks
        policy = ("speculative" if cfg.hessian_policy == "exact_speculative"
                  else "wait_all")
        mem_bytes = scheduler.sketch_worker_bytes(b, min(d, b))
        try:
            run(workers, policy, flops=block_flops, comm=0.05 * workers,
                mem=_phase_mem(cfg.phase_memory, mem_bytes),
                ws=_ws_gb(mem_bytes))
        except PhaseExhaustedError:
            if cfg.fault_fallback == "raise":
                raise
            # Attempts billed; the exact product is deterministic, so the
            # master's local recompute stands in for the lost round.
            _telemetry(clock).metrics.counter(
                "newton.fault_fallbacks").inc()
    return _jitted_exact_hessian(objective)(w, data), None


def _distavg_direction_phase(objective, data: Dataset, w: jax.Array,
                             g: jax.Array, cfg: NewtonConfig, key: jax.Array,
                             clock: Optional[straggler.SimClock],
                             dag: Optional[scheduler.DagRun] = None,
                             grad_dep: Optional[str] = None,
                             tag: str = "distavg"
                             ) -> Tuple[jax.Array, jax.Array]:
    """sketch_mode="distributed-avg": one worker per sketch block, each
    paying its apply + d x d Gram + local Cholesky solve; the master only
    ships d-vectors back (comm ~ d per worker, not a d x d Gram tile).
    Returns (direction, averaged H_k g for the weakly-convex search).

    With ``dag`` the round splits at its true data dependency, the way
    Bartan-Pilanci's analysis assumes it is dispatched: the SKETCH phase
    (apply + per-block Gram, a function of w only) launches concurrently
    with the gradient round, and the SOLVE phase (needs g shipped to the
    survivors) runs after both.  The survivor mask comes from the sketch
    phase under the same key as the sequential combined phase; under the
    default all-off fleet lifecycle the duration ORDER is scale-invariant
    in the per-worker flop count, so the mask — hence the direction — is
    schedule-invariant.  With cold starts or failures enabled the split
    phase's smaller flop count can reorder arrivals (additive delays vs
    multiplicative work), so masks may differ between schedules there —
    honest modelling of the split round, not a bug."""
    n_rows, d = _hess_rows(objective, data, w)
    scfg = cfg.sketch
    fam = sketching.get(cfg.sketch_family, scfg)
    survivors = jnp.ones((scfg.total_blocks,), bool)
    if clock is not None:
        # No coded-matmul stage to amortize into here, so a family that
        # reports apply_flops=0 (oversketch) still pays one streaming pass
        # over A on each worker.
        apply_flops = fam.apply_flops(n_rows, d) or 2.0 * n_rows * d
        gram_flops = 2.0 * scfg.block_size * d * d
        solve_flops = (d ** 3 / 3.0 if cfg.distavg_solver == "chol"
                       else 2.0 * cfg.cg_iters * d * d)   # cg matvecs
        mem_bytes = scheduler.distavg_worker_bytes(scfg.block_size, d)
        mem = _phase_mem(cfg.phase_memory, mem_bytes)
        ws = _ws_gb(mem_bytes)
        try:
            if dag is not None:
                sk = dag.dispatch(scheduler.PhaseSpec(
                    name=f"{tag}-sketch", workers=scfg.total_blocks,
                    policy="k_of_n", k=scfg.num_blocks,
                    flops_per_worker=apply_flops + gram_flops,
                    comm_units=0.01 * scfg.total_blocks, memory_gb=mem,
                    working_set_gb=ws), key=key)
                survivors = sk.mask
                # An exhausted gradient phase never registers with the
                # DAG; keep only edges to phases that actually exist and
                # let the barrier at the current clock stand in for the
                # missing one (same convention as GIANT's chain).
                want = (f"{tag}-sketch",) + \
                    ((grad_dep,) if grad_dep is not None else ())
                deps = tuple(dd for dd in want if dd in dag.results)
                dag.dispatch(scheduler.PhaseSpec(
                    name=f"{tag}-solve", workers=scfg.num_blocks,
                    policy="wait_all", flops_per_worker=solve_flops,
                    comm_units=0.01 * scfg.num_blocks, memory_gb=mem,
                    working_set_gb=ws, deps=deps),
                    key=jax.random.fold_in(key, 11),
                    sequential=len(deps) < len(want))
            else:
                _, mask = clock.phase(key, scfg.total_blocks,
                                      policy="k_of_n",
                                      k=scfg.num_blocks,
                                      flops_per_worker=(apply_flops
                                                        + gram_flops
                                                        + solve_flops),
                                      comm_units=0.01 * scfg.total_blocks,
                                      memory_gb=mem, working_set_gb=ws,
                                      phase_name=tag)
                survivors = mask
        except PhaseExhaustedError as e:
            if cfg.fault_fallback == "raise":
                raise
            # Exhausted retry budget: every attempt is billed; the
            # finite-finisher mask stands in for the k-of-n survivors
            # (per-block directions are independently unbiased, so the
            # average over fewer blocks just carries more variance — the
            # caller's descent guard backstops a zero-survivor round).
            _telemetry(clock).metrics.counter(
                "newton.fault_fallbacks").inc()
            if e.mask.shape == (scfg.total_blocks,):
                survivors = jnp.asarray(e.mask)
    state = fam.sample(jax.random.fold_in(key, 7), n_rows)
    fn = _jitted_distavg_direction(objective, fam, cfg.debias,
                                   cfg.use_kernels, cfg.distavg_solver,
                                   cfg.cg_iters)
    return fn(w, data, g, state, survivors)


def oversketched_newton(objective, data: Dataset, w0: jax.Array,
                        cfg: NewtonConfig,
                        model: Optional[straggler.StragglerModel] = straggler.StragglerModel()
                        ) -> NewtonResult:
    """Run OverSketched Newton; returns the iterate and a per-iteration log.

    ``model`` is either a ``StragglerModel`` (a fresh default fleet clock is
    built) or a prebuilt ``straggler.SimClock`` — the way to score a run on
    a custom fleet (cold starts, failures, trace record/replay; see
    ``repro.runtime``).  ``history["cost"]`` logs cumulative simulated
    dollars alongside ``history["time"]``'s simulated seconds.
    """
    if cfg.sketch_mode not in ("blocks", "distributed-avg"):
        raise ValueError(f"unknown sketch_mode {cfg.sketch_mode!r}")
    if cfg.distavg_solver not in ("chol", "cg"):
        raise ValueError(f"unknown distavg_solver {cfg.distavg_solver!r}")
    if cfg.schedule not in ("dag", "sequential"):
        raise ValueError(f"unknown schedule {cfg.schedule!r}")
    if cfg.adaptive_metric not in ("stall", "mp"):
        raise ValueError(f"unknown adaptive_metric {cfg.adaptive_metric!r}")
    if cfg.fault_fallback not in ("degrade", "raise"):
        raise ValueError(f"unknown fault_fallback {cfg.fault_fallback!r}")
    if not 0.0 < cfg.survivor_floor <= 1.0:
        raise ValueError(
            f"survivor_floor must be in (0, 1], got {cfg.survivor_floor}")
    if (cfg.adaptive_sketch and cfg.adaptive_metric == "mp"
            and (cfg.sketch_mode != "blocks"
                 or cfg.hessian_policy != "oversketch")):
        raise ValueError(
            "adaptive_metric='mp' needs the surviving sketch-row count, "
            "which only the sketch_mode='blocks' + "
            "hessian_policy='oversketch' path reports")
    if cfg.sketch_mode == "distributed-avg":
        if cfg.hessian_policy != "oversketch":
            raise ValueError(
                "sketch_mode='distributed-avg' requires "
                f"hessian_policy='oversketch', got {cfg.hessian_policy!r}")
        d_hess = int(np.asarray(w0).size)
        if cfg.sketch.block_size <= d_hess:
            raise ValueError(
                "distributed-avg needs block_size > Hessian dim for the "
                f"per-worker solves to be well-posed: block_size="
                f"{cfg.sketch.block_size} <= d={d_hess}")
    sketching.get(cfg.sketch_family, cfg.sketch)   # fail fast on bad family
    key = jax.random.PRNGKey(cfg.seed)
    if isinstance(model, straggler.SimClock):
        clock, model = model, model.model
    else:
        clock = straggler.SimClock(model) if model is not None else None
    engine = CodedMatvecEngine(data, cfg.coded_block_rows, model,
                               overlap_encode=cfg.overlap_encode,
                               phase_memory=cfg.phase_memory,
                               corruption_detection=cfg.corruption_detection)

    w = jnp.asarray(w0, jnp.float32)
    hist: Dict[str, List[float]] = {k: [] for k in (
        "iter", "fval", "gnorm", "step", "time", "cost", "test_error",
        "sketch_dim")}

    grad_fn = jax.jit(objective.gradient)
    val_fn = jax.jit(objective.value)
    live_cfg = cfg
    init_sketch_dim = cfg.sketch.sketch_dim   # growth cap baseline; cfg is
    #                                           rebound to live_cfg below
    prev_f = None
    prev_decrease = None

    tel = _telemetry(clock)
    run_span = tel.trace.begin(
        "newton", "run", clock.time if clock is not None else 0.0,
        sketch_family=cfg.sketch_family, schedule=cfg.schedule,
        sketch_mode=cfg.sketch_mode)
    if tel.enabled and cfg.solver in ("cg", "minres"):
        tel.metrics.gauge("newton.cg_iters").set(cfg.cg_iters)

    for t in range(cfg.iters):
        cfg = live_cfg
        key, kg, kh, kl = jax.random.split(key, 4)
        it_span = tel.trace.begin(
            f"iter{t}", "iteration",
            clock.time if clock is not None else float(t))
        # One iteration = one phase DAG: gradient matvecs chain through
        # dependency edges, the Hessian sketch is a root node launched at
        # the iteration start (concurrent with the gradient), the line
        # search joins both.  schedule="sequential" keeps the historical
        # one-phase-at-a-time dispatch; the phase keys — hence masks and
        # iterates — are the same either way.
        dag = (scheduler.DagRun(clock, key=key)
               if cfg.schedule == "dag" and clock is not None else None)

        # --- 1. gradient (straggler-resilient coded matvecs, Alg. 1) -------
        grad_tail = None
        if cfg.gradient_policy == "exact" or model is None:
            g = grad_fn(w, data)
        else:
            # Fixed per-tag fold constants: Python's str hash is salted
            # per process, which would break cross-process seed
            # reproducibility of the straggler samples.
            mv_seq = {"n": 0}

            def mv(tag, v):
                kf = jax.random.fold_in(kg, {"X": 3, "XT": 5}[tag])
                if dag is None:
                    return engine.matvec(tag, v, clock, kf,
                                         cfg.gradient_policy)
                after = (dag.last,) if dag.last is not None else ()
                y = engine.matvec(tag, v, clock, kf, cfg.gradient_policy,
                                  dag=dag,
                                  name=f"grad/{mv_seq['n']}:{tag}",
                                  after=after)
                mv_seq["n"] += 1
                return y

            g = objective.gradient_via(w, data, mv)
            if dag is not None:
                grad_tail = dag.last

        # --- 2+3. sketched Hessian (Alg. 2) and direction -------------------
        m_eff = None
        if cfg.sketch_mode == "distributed-avg":
            # per-worker solves + master-side direction averaging
            p, hg = _distavg_direction_phase(objective, data, w, g, cfg,
                                             kh, clock, dag=dag,
                                             grad_dep=grad_tail)
        else:
            h_hat, m_eff = _hessian_phase(objective, data, w, cfg, kh,
                                          clock, dag=dag)
            if h_hat is None:
                # Fault degradation: the sketch round (and its re-dispatch)
                # lost too many blocks — take a plain gradient step, with
                # hg = g (H = I) keeping the weakly-convex search coherent.
                p, hg = -g, g
                tel.metrics.counter("newton.gradient_fallbacks").inc()
            else:
                p = _solve_direction(objective, h_hat, g, cfg)
                if cfg.debias and m_eff is not None:
                    p = sketching.debias_direction(p, p.shape[0], m_eff)
                hg = None

        # Descent guard: whatever produced p (a starved sketch, a debias
        # factor driven past zero by casualties, a corrupted Hessian
        # estimate that slipped through), only a finite descent direction
        # may reach the line search — anything else degrades to steepest
        # descent instead of diverging.
        gp = float(jnp.vdot(g, p))
        if not math.isfinite(gp) or gp >= 0.0:
            p, hg = -g, g
            tel.metrics.counter("newton.safeguard_fallbacks").inc()

        # --- 4. distributed line search (Sec. 3.2) --------------------------
        if cfg.unit_step:
            step = jnp.asarray(1.0)
        elif objective.strongly_convex:
            step = linesearch.linesearch_strongly_convex(
                objective, data, w, p, g, cfg.beta, cfg.candidates)
        else:
            if hg is None:
                hg = h_hat @ g
            step = linesearch.linesearch_weakly_convex(
                objective, data, w, p, g, hg, cfg.beta, cfg.candidates)
        if clock is not None and not cfg.unit_step:
            nb = max(1, data.x.shape[0] // max(cfg.coded_block_rows, 1))
            ls_flops = 2.0 * cfg.coded_block_rows * data.x.shape[1] * \
                len(cfg.candidates)
            ls_bytes = scheduler.matvec_worker_bytes(
                cfg.coded_block_rows, data.x.shape[1])
            ls_mem = _phase_mem(cfg.phase_memory, ls_bytes)
            try:
                if dag is not None:
                    # The line search consumes p, i.e. every phase so far;
                    # by then the clock already sits at the DAG's frontier,
                    # so it dispatches on the engine's exact sequential
                    # path.  The edges are still declared (sequential
                    # dispatch ignores them for timing) so the recorded
                    # DAG joins here and the critical-path walk can cross
                    # the line search.
                    dag.dispatch(scheduler.PhaseSpec(
                        name="linesearch", workers=nb, policy="wait_all",
                        flops_per_worker=ls_flops, comm_units=0.5,
                        memory_gb=ls_mem, working_set_gb=_ws_gb(ls_bytes),
                        deps=tuple(dag.results)),
                        key=kl, sequential=True)
                else:
                    clock.phase(kl, nb, policy="wait_all",
                                flops_per_worker=ls_flops, comm_units=0.5,
                                memory_gb=ls_mem,
                                working_set_gb=_ws_gb(ls_bytes),
                                phase_name="linesearch")
            except PhaseExhaustedError:
                if cfg.fault_fallback == "raise":
                    raise
                # Billed, lost: the search objective values are master-side
                # math, so the chosen step survives the dead fan-out.
                tel.metrics.counter("newton.fault_fallbacks").inc()

        w = w + step * p

        hist["iter"].append(t)
        f_now = float(val_fn(w, data))
        hist["fval"].append(f_now)
        hist["gnorm"].append(float(jnp.linalg.norm(grad_fn(w, data))))
        hist["step"].append(float(step))
        hist["time"].append(clock.time if clock is not None else float(t + 1))
        hist["cost"].append(clock.dollars if clock is not None else 0.0)
        hist["sketch_dim"].append(live_cfg.sketch.sketch_dim)

        if tel.enabled:
            tel.metrics.gauge("newton.sketch_dim").set(
                live_cfg.sketch.sketch_dim)
            # Per-iteration seconds/dollars deltas: the cost-per-iteration
            # streams the online health monitors watch for blowups.
            many = len(hist["time"]) > 1
            tel.metrics.gauge("newton.iter_seconds").set(
                hist["time"][-1] - (hist["time"][-2] if many else 0.0))
            tel.metrics.gauge("newton.iter_dollars").set(
                hist["cost"][-1] - (hist["cost"][-2] if many else 0.0))
            if cfg.solver in ("cg", "minres"):
                tel.metrics.gauge("newton.cg_iters").set(cfg.cg_iters)
            if dag is not None and dag.results:
                # Per-iteration critical-path + slack report (ROADMAP's
                # DagResult analytics item), attached to the iteration
                # span so exporters and make_report can render it.
                rep = dag.critical_path()
                tel.trace.set_attrs(
                    it_span,
                    critical_path=list(rep.critical_path),
                    dag_makespan=rep.makespan,
                    slack={n: p.slack for n, p in rep.phases.items()})
        tel.trace.end(it_span,
                      clock.time if clock is not None else float(t + 1))

        # --- adaptive sketch growth (paper Thm 3.2 remark) ------------------
        if cfg.adaptive_sketch:
            if cfg.adaptive_metric == "mp":
                # Grow when the MEASURED Marchenko-Pastur factor of the
                # surviving sketch rows says the sketch is too biased to
                # trust — a leading indicator available from iteration 0,
                # unlike the trailing f-decrease stall below.
                stalled = m_eff is not None and sketching.mp_stalled(
                    int(p.shape[0]), m_eff, cfg.adaptive_mp_target)
            elif prev_f is not None:
                decrease = prev_f - f_now
                # Stall = progress fell off vs the last iteration; an
                # INCREASE in f (decrease < 0, the eps-too-coarse
                # divergence regime) is always a stall, whatever the
                # previous decrease was.
                stalled = decrease < 0 or (
                    prev_decrease is not None and prev_decrease > 0
                    and decrease < cfg.adaptive_stall_ratio * prev_decrease)
            else:
                stalled = False
            grown = live_cfg.sketch.sketch_dim // init_sketch_dim
            if stalled and grown < cfg.adaptive_max_growth:
                new_sketch = dataclasses.replace(
                    live_cfg.sketch,
                    sketch_dim=live_cfg.sketch.sketch_dim * 2)
                live_cfg = dataclasses.replace(live_cfg, sketch=new_sketch)
                tel.metrics.counter("newton.adaptive_growth").inc()
        if prev_f is not None:
            prev_decrease = prev_f - f_now
        prev_f = f_now
        if cfg.track_test_error and data.x_test is not None:
            hist["test_error"].append(
                float(objective.error(w, data.x_test, data.y_test)))
        else:
            hist["test_error"].append(float("nan"))

    tel.trace.end(run_span,
                  clock.time if clock is not None else float(cfg.iters))
    return NewtonResult(w=w, history=hist)
