"""OverSketched Newton (paper Alg. 3 / Alg. 4): the master loop.

Master-side Python loop (the paper's T is in the tens) dispatching jitted
distributed phases:

  1. gradient  — exact, straggler-resilient via the 2-D product code (Alg. 1)
  2. Hessian   — approximate, straggler-resilient via OverSketch (Alg. 2)
  3. direction — Cholesky/CG (strongly convex) or pinv/MINRES (weakly convex)
  4. step size — distributed Armijo (Eq. 5) / grad-norm (Eq. 6) line search

Each distributed phase is scored by the straggler simulation clock
(`core.straggler`), which is how the paper's wall-clock comparisons are
reproduced on a single-device container.
"""
from __future__ import annotations

import dataclasses
import functools
from functools import partial
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

import numpy as np

from repro.core import coded, linesearch, sketch, solvers, straggler
from repro.core.objectives import Dataset


def _decodable(erased_grid: "np.ndarray") -> bool:
    """Host-side peeling feasibility check on the (g+1)x(g+1) erasure grid.
    Mirrors coded.peel_decode: a line with exactly one missing cell can be
    recovered; iterate to fixpoint."""
    known = ~erased_grid.copy()
    g1 = known.shape[0]
    for _ in range(2 * g1):
        if known.all():
            return True
        progress = False
        for axis in (0, 1):
            missing = (~known).sum(axis=axis)
            for i in np.where(missing == 1)[0]:
                if axis == 0:
                    j = int(np.argmin(known[:, i]))
                    known[j, i] = True
                else:
                    j = int(np.argmin(known[i, :]))
                    known[i, j] = True
                progress = True
        if not progress:
            return False
    return bool(known.all())


@dataclasses.dataclass(frozen=True)
class NewtonConfig:
    iters: int = 20
    sketch: sketch.OverSketchConfig = dataclasses.field(
        default_factory=lambda: sketch.OverSketchConfig(
            sketch_dim=2048, block_size=256, straggler_tolerance=0.25))
    beta: float = 0.1
    candidates: tuple = linesearch.DEFAULT_CANDIDATES
    unit_step: bool = False
    solver: str = "auto"            # auto | chol | cg | pinv | minres
    cg_iters: int = 64
    gradient_policy: str = "coded"  # coded | wait_all | ignore | speculative
    hessian_policy: str = "oversketch"   # oversketch | exact | exact_speculative
    coded_block_rows: int = 256
    seed: int = 0
    use_kernels: bool = False       # route sketch through repro.kernels ops
    track_test_error: bool = False
    # Paper Thm 3.2 remark: "the sketch dimension can be increased to reduce
    # eps ... and improve the convergence rate in practice" — when iteration
    # progress stalls (the eps-linear tail), double the sketch dimension.
    adaptive_sketch: bool = False
    adaptive_stall_ratio: float = 0.25   # f-decrease ratio that counts as a stall
    adaptive_max_growth: int = 4         # cap: sketch_dim <= 4x initial


@dataclasses.dataclass
class NewtonResult:
    w: jax.Array
    history: Dict[str, List[float]]


class CodedMatvecEngine:
    """Holds the one-time 2-D product-code encodings of X and X^T (the paper
    amortizes encoding across iterations, Sec. 4.1) and serves straggler-
    resilient matvecs."""

    def __init__(self, data: Dataset, block_rows: int,
                 model: Optional[straggler.StragglerModel]):
        self.model = model
        n, d = data.x.shape
        br_n = max(1, min(block_rows, n))
        br_d = max(1, min(block_rows, d))
        self.code_x = coded.make_code(n, br_n)      # for X @ v    (n rows)
        self.code_xt = coded.make_code(d, br_d)     # for X^T @ v  (d rows)
        self.enc_x = coded.encode_2d(data.x, self.code_x)
        self.enc_xt = coded.encode_2d(data.x.T, self.code_xt)
        self.out_rows = {"X": n, "XT": d}
        self.fallbacks = 0

        @partial(jax.jit, static_argnames=("tag",))
        def _mv(tag, v, erased):
            enc = self.enc_x if tag == "X" else self.enc_xt
            code = self.code_x if tag == "X" else self.code_xt
            return coded.coded_matvec(enc, v, code, self.out_rows[tag], erased)

        self._mv = _mv

    def code_for(self, tag: str) -> coded.ProductCode:
        return self.code_x if tag == "X" else self.code_xt

    def matvec(self, tag: str, v: jax.Array, clock: straggler.SimClock,
               key: jax.Array, policy: str) -> jax.Array:
        code = self.code_for(tag)
        w = code.num_workers
        enc = self.enc_x if tag == "X" else self.enc_xt
        flops = 2.0 * code.block_rows * enc.shape[-1]   # one block matvec
        erased = None
        if self.model is not None and policy == "coded":
            # Faithful master: results stream in; decode starts as soon as
            # the arrived set is peelable (paper Alg. 1 step 8).
            times = np.asarray(self.model.sample_times(
                key, w, flops_per_worker=flops))
            order = np.argsort(times)
            g1 = code.grid + 1
            k_min = max(1, w - (2 * code.grid + 1))
            elapsed = times[order[-1]]
            chosen = w
            for k in range(k_min, w + 1):
                mask = np.zeros(w, bool)
                mask[order[:k]] = True
                if _decodable(mask.reshape(g1, g1)):
                    elapsed = times[order[k - 1]]
                    chosen = k
                    break
            mask = np.zeros(w, bool)
            mask[order[:chosen]] = True
            clock.charge(float(elapsed) +
                         self.model.comm_per_unit * 1.0)
            erased = jnp.asarray(~mask).reshape(g1, g1)
        elif self.model is not None and policy == "wait_all":
            clock.phase(key, w, policy="wait_all", flops_per_worker=flops,
                        comm_units=1.0)
        elif self.model is not None and policy == "speculative":
            clock.phase(key, w, policy="speculative",
                        flops_per_worker=flops, comm_units=1.0)
        elif self.model is not None and policy == "ignore":
            # mini-batch style: drop stragglers' contributions entirely —
            # handled by the caller using an uncoded gradient; we still pay
            # the k-of-n time.
            k = max(1, int(0.95 * w))
            clock.phase(key, w, policy="k_of_n", k=k,
                        flops_per_worker=flops, comm_units=1.0)
        y, ok = self._mv(tag, v, erased)
        if erased is not None and not bool(ok):
            # Decode failure (erasure pattern beyond the code): the paper's
            # master re-launches stragglers; charge a full re-execution round.
            self.fallbacks += 1
            y, _ = self._mv(tag, v, None)
            if self.model is not None:
                clock.phase(jax.random.fold_in(key, 1), w,
                            policy="wait_all", comm_units=1.0)
        return y


def _solve_direction(objective, h_hat: jax.Array, g: jax.Array,
                     cfg: NewtonConfig) -> jax.Array:
    solver = cfg.solver
    if solver == "auto":
        solver = "chol" if objective.strongly_convex else "pinv"
    if solver == "chol":
        return -solvers.psd_solve(h_hat, g)
    if solver == "cg":
        return -solvers.conjugate_gradient(lambda v: h_hat @ v, g,
                                           jnp.zeros_like(g), cfg.cg_iters)
    if solver == "pinv":
        return -solvers.psd_pinv_solve(h_hat, g)
    if solver == "minres":
        return -solvers.minres(lambda v: h_hat @ v, g, cfg.cg_iters)
    raise ValueError(solver)


@functools.lru_cache(maxsize=64)
def _jitted_sketched_hessian(objective, block_size: int, use_kernels: bool):
    """Hashable frozen-dataclass objectives => cacheable jitted closures."""
    def fn(w, data, h, sigma, survivors):
        a = objective.hess_sqrt(w, data)
        d = a.shape[1]
        reg = objective.hess_reg * jnp.eye(d, dtype=a.dtype)
        if use_kernels:
            from repro.kernels import ops as kops
            a_t = kops.count_sketch_apply(h, sigma, a, block_size)
            return kops.oversketch_gram(a_t, survivors) + reg
        cs = sketch.CountSketch(h=h, sigma=sigma, block_size=block_size)
        a_t = sketch.apply_sketch(cs, a)
        return sketch.sketched_gram(a_t, survivors) + reg
    return jax.jit(fn)


@functools.lru_cache(maxsize=64)
def _jitted_exact_hessian(objective):
    def fn(w, data):
        a = objective.hess_sqrt(w, data)
        d = a.shape[1]
        return a.T @ a + objective.hess_reg * jnp.eye(d, dtype=a.dtype)
    return jax.jit(fn)


def _hess_rows(objective, data: Dataset, w: jax.Array) -> Tuple[int, int]:
    shape = jax.eval_shape(objective.hess_sqrt, w, data).shape
    return shape[0], shape[1]


def _hessian_phase(objective, data: Dataset, w: jax.Array, cfg: NewtonConfig,
                   key: jax.Array, clock: Optional[straggler.SimClock]
                   ) -> jax.Array:
    """Returns H_hat (approximate or exact) including the hess_reg * I term.

    Worker accounting follows the paper: OverSketch invokes (N+e)*(d/b)^2
    workers (Alg. 2 step 3) vs ceil(n/b)*(d/b)^2 for the exact product —
    same per-worker block work, vastly different worker counts and master
    I/O when n >> m."""
    n_rows, d = _hess_rows(objective, data, w)
    b = max(cfg.sketch.block_size, 1)
    d_blocks = max(1, -(-d // b))
    block_flops = 2.0 * b * min(d, b) ** 2    # one (b x d_tile) gram block
    if cfg.hessian_policy == "oversketch":
        scfg = cfg.sketch
        survivors = jnp.ones((scfg.total_blocks,), bool)
        if clock is not None:
            # Alg. 2 termination is per OUTPUT TILE: each of the (d/b)^2
            # tiles waits for any N of its N+e sketch-block workers.  The
            # tile groups run in parallel (phase time ~ one k-of-n round);
            # the master I/O scales with the full worker count.
            total_workers = scfg.total_blocks * d_blocks * d_blocks
            _, mask = clock.phase(key, scfg.total_blocks, policy="k_of_n",
                                  k=scfg.num_blocks,
                                  flops_per_worker=block_flops,
                                  comm_units=0.05 * total_workers)
            survivors = mask
        cs = sketch.sample_countsketch(jax.random.fold_in(key, 7),
                                       n_rows, scfg)
        fn = _jitted_sketched_hessian(objective, scfg.block_size,
                                      cfg.use_kernels)
        return fn(w, data, cs.h, cs.sigma, survivors)
    # exact Hessian (paper's "exact Newton" baseline)
    if clock is not None:
        workers = max(1, -(-n_rows // b)) * d_blocks * d_blocks
        policy = ("speculative" if cfg.hessian_policy == "exact_speculative"
                  else "wait_all")
        clock.phase(key, workers, policy=policy,
                    flops_per_worker=block_flops,
                    comm_units=0.05 * workers)
    return _jitted_exact_hessian(objective)(w, data)


def oversketched_newton(objective, data: Dataset, w0: jax.Array,
                        cfg: NewtonConfig,
                        model: Optional[straggler.StragglerModel] = straggler.StragglerModel()
                        ) -> NewtonResult:
    """Run OverSketched Newton; returns the iterate and a per-iteration log."""
    key = jax.random.PRNGKey(cfg.seed)
    clock = straggler.SimClock(model) if model is not None else None
    engine = CodedMatvecEngine(data, cfg.coded_block_rows, model)

    w = jnp.asarray(w0, jnp.float32)
    hist: Dict[str, List[float]] = {k: [] for k in (
        "iter", "fval", "gnorm", "step", "time", "test_error",
        "sketch_dim")}

    grad_fn = jax.jit(objective.gradient)
    val_fn = jax.jit(objective.value)
    live_cfg = cfg
    prev_f = None
    prev_decrease = None

    for t in range(cfg.iters):
        cfg = live_cfg
        key, kg, kh, kl = jax.random.split(key, 4)

        # --- 1. gradient (straggler-resilient coded matvecs, Alg. 1) -------
        if cfg.gradient_policy == "exact" or model is None:
            g = grad_fn(w, data)
        else:
            mv = lambda tag, v: engine.matvec(
                tag, v, clock, jax.random.fold_in(kg, hash(tag) % 997),
                cfg.gradient_policy)
            g = objective.gradient_via(w, data, mv)

        # --- 2. sketched Hessian (Alg. 2) ----------------------------------
        h_hat = _hessian_phase(objective, data, w, cfg, kh, clock)

        # --- 3. direction at the master ------------------------------------
        p = _solve_direction(objective, h_hat, g, cfg)

        # --- 4. distributed line search (Sec. 3.2) --------------------------
        if cfg.unit_step:
            step = jnp.asarray(1.0)
        elif objective.strongly_convex:
            step = linesearch.linesearch_strongly_convex(
                objective, data, w, p, g, cfg.beta, cfg.candidates)
        else:
            step = linesearch.linesearch_weakly_convex(
                objective, data, w, p, g, h_hat @ g, cfg.beta, cfg.candidates)
        if clock is not None and not cfg.unit_step:
            nb = max(1, data.x.shape[0] // max(cfg.coded_block_rows, 1))
            ls_flops = 2.0 * cfg.coded_block_rows * data.x.shape[1] * \
                len(cfg.candidates)
            clock.phase(kl, nb, policy="wait_all",
                        flops_per_worker=ls_flops, comm_units=0.5)

        w = w + step * p

        hist["iter"].append(t)
        f_now = float(val_fn(w, data))
        hist["fval"].append(f_now)
        hist["gnorm"].append(float(jnp.linalg.norm(grad_fn(w, data))))
        hist["step"].append(float(step))
        hist["time"].append(clock.time if clock is not None else float(t + 1))
        hist["sketch_dim"].append(live_cfg.sketch.sketch_dim)

        # --- adaptive sketch growth (paper Thm 3.2 remark) ------------------
        if cfg.adaptive_sketch and prev_f is not None and \
                prev_decrease is not None and prev_decrease > 0:
            decrease = prev_f - f_now
            stalled = decrease < cfg.adaptive_stall_ratio * prev_decrease
            grown = live_cfg.sketch.sketch_dim // cfg.sketch.sketch_dim
            if stalled and grown < cfg.adaptive_max_growth:
                new_sketch = dataclasses.replace(
                    live_cfg.sketch,
                    sketch_dim=live_cfg.sketch.sketch_dim * 2)
                live_cfg = dataclasses.replace(live_cfg, sketch=new_sketch)
        if prev_f is not None:
            prev_decrease = prev_f - f_now
        prev_f = f_now
        if cfg.track_test_error and data.x_test is not None:
            hist["test_error"].append(
                float(objective.error(w, data.x_test, data.y_test)))
        else:
            hist["test_error"].append(float("nan"))

    return NewtonResult(w=w, history=hist)
