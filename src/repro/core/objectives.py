"""Convex objectives with the (gradient-as-matvec, Hessian-square-root)
structure that OverSketched Newton exploits (paper Sec. 4).

Conventions (row-major, mean-normalized):
  features X: (n, d) with samples as rows;  logistic labels y in {-1, +1}.
  logistic:  f(w) = (1/n) sum log(1 + exp(-y_i x_i.w)) + (lam/2)||w||^2
  softmax:   W (K, d) class-major, flat dim K*d, mean-normalized NLL,
             unregularized => weakly convex (paper Sec. 4.2).
  ridge:     f(w) = (1/2n)||Xw - y||^2 + (lam/2)||w||^2
  lp_ipm:    f(x) = tau c.x - sum_i log(b_i - a_i.x)   (interior point stage)

Every objective provides:
  value(w, data), gradient(w, data)
  hess_sqrt(w, data) -> A with  grad^2 f = A^T A + hess_reg * I
  gradient_via(w, data, mv) -> gradient where every large matvec goes through
     mv(tag, v): tag in {"X", "XT"} — the hook the coded/straggler-resilient
     distributed path plugs into (paper Alg. 1 usage).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp


class Dataset(NamedTuple):
    x: jax.Array          # (n, d) features
    y: jax.Array          # (n,) labels (+-1) or (n, K) one-hot
    x_test: Optional[jax.Array] = None
    y_test: Optional[jax.Array] = None


MatVec = Callable[[str, jax.Array], jax.Array]


def _plain_mv(data: Dataset) -> MatVec:
    def mv(tag: str, v: jax.Array) -> jax.Array:
        if tag == "X":
            return data.x @ v
        if tag == "XT":
            return data.x.T @ v
        raise ValueError(tag)
    return mv


@dataclasses.dataclass(frozen=True)
class LogisticRegression:
    lam: float = 1e-5
    strongly_convex: bool = True
    name: str = "logistic"

    @property
    def hess_reg(self) -> float:
        return self.lam

    def value(self, w: jax.Array, data: Dataset) -> jax.Array:
        margins = data.y * (data.x @ w)
        # log1p(exp(-m)) stable via softplus(-m)
        return jnp.mean(jax.nn.softplus(-margins)) + 0.5 * self.lam * w @ w

    def gradient_via(self, w: jax.Array, data: Dataset,
                     mv: Optional[MatVec] = None) -> jax.Array:
        mv = mv or _plain_mv(data)
        n = data.x.shape[0]
        alpha = mv("X", w)                                   # (n,)
        beta = -data.y * jax.nn.sigmoid(-data.y * alpha)     # -y/(1+e^{y a})
        return mv("XT", beta) / n + self.lam * w

    def gradient(self, w: jax.Array, data: Dataset) -> jax.Array:
        return self.gradient_via(w, data)

    def hess_sqrt(self, w: jax.Array, data: Dataset) -> jax.Array:
        """A = sqrt(Lam/n) X, Lam_ii = sig(y a)(1 - sig(y a))."""
        n = data.x.shape[0]
        alpha = data.x @ w
        s = jax.nn.sigmoid(data.y * alpha)
        lam_diag = s * (1.0 - s)
        return jnp.sqrt(lam_diag / n)[:, None] * data.x

    def masked_value(self, w: jax.Array, data: Dataset,
                     row_ok: jax.Array) -> jax.Array:
        """Mean loss over surviving rows only (ignore-stragglers scheme)."""
        margins = data.y * (data.x @ w)
        loss = jax.nn.softplus(-margins) * row_ok
        return loss.sum() / jnp.maximum(row_ok.sum(), 1.0) \
            + 0.5 * self.lam * w @ w

    def error(self, w: jax.Array, x: jax.Array, y: jax.Array) -> jax.Array:
        return jnp.mean(jnp.sign(x @ w) != y)


@dataclasses.dataclass(frozen=True)
class SoftmaxRegression:
    """Unregularized multinomial logistic regression — weakly convex.

    Parameters are a flat vector w of length K*d (class-major), matching the
    paper's dK-dimensional Hessian treatment (Sec. 4.2).
    """
    num_classes: int
    lam: float = 0.0
    strongly_convex: bool = False
    name: str = "softmax"

    @property
    def hess_reg(self) -> float:
        return self.lam

    def _unflatten(self, w: jax.Array, d: int) -> jax.Array:
        return w.reshape(self.num_classes, d)

    def value(self, w: jax.Array, data: Dataset) -> jax.Array:
        d = data.x.shape[1]
        logits = data.x @ self._unflatten(w, d).T            # (n, K)
        nll = jax.nn.logsumexp(logits, axis=1) - (logits * data.y).sum(axis=1)
        return jnp.mean(nll) + 0.5 * self.lam * w @ w

    def gradient_via(self, w: jax.Array, data: Dataset,
                     mv: Optional[MatVec] = None) -> jax.Array:
        mv = mv or _plain_mv(data)
        n, d = data.x.shape
        # alpha: (n, K) via K matvecs through the hook (paper computes X^T W).
        wk = self._unflatten(w, d)
        alpha = jnp.stack([mv("X", wk[k]) for k in range(self.num_classes)],
                          axis=1)
        p = jax.nn.softmax(alpha, axis=1)
        beta = (p - data.y) / n                              # (n, K)
        g = jnp.stack([mv("XT", beta[:, k]) for k in range(self.num_classes)],
                      axis=0)
        return g.reshape(-1) + self.lam * w

    def gradient(self, w: jax.Array, data: Dataset) -> jax.Array:
        d = data.x.shape[1]
        logits = data.x @ self._unflatten(w, d).T
        p = jax.nn.softmax(logits, axis=1)
        g = (p - data.y).T @ data.x / data.x.shape[0]        # (K, d)
        return g.reshape(-1) + self.lam * w

    def hess_sqrt(self, w: jax.Array, data: Dataset) -> jax.Array:
        """A (n*K, d*K) with A^T A = Hessian (class-major blocks).

        Per-sample PSD factor: B_n = diag(p_n) - p_n p_n^T = M_n M_n^T with
        M_n = diag(sqrt(p_n)) - p_n sqrt(p_n)^T  (verified in tests).
        """
        n, d = data.x.shape
        k = self.num_classes
        logits = data.x @ self._unflatten(w, d).T
        p = jax.nn.softmax(logits, axis=1)                   # (n, K)
        sq = jnp.sqrt(p)
        m = (jnp.eye(k)[None] * sq[:, None, :]) - p[..., None] * sq[:, None, :]
        # rows (n, c): A[(n,c), (i, j)] = M_n[i, c] * x_n[j] / sqrt(n)
        a = jnp.einsum("nic,nj->ncij", m, data.x) / jnp.sqrt(n)
        return a.reshape(n * k, k * d)

    def masked_value(self, w: jax.Array, data: Dataset,
                     row_ok: jax.Array) -> jax.Array:
        d = data.x.shape[1]
        logits = data.x @ self._unflatten(w, d).T
        nll = jax.nn.logsumexp(logits, axis=1) - (logits * data.y).sum(axis=1)
        return (nll * row_ok).sum() / jnp.maximum(row_ok.sum(), 1.0) \
            + 0.5 * self.lam * w @ w

    def error(self, w: jax.Array, x: jax.Array, y: jax.Array) -> jax.Array:
        d = x.shape[1]
        pred = jnp.argmax(x @ self._unflatten(w, d).T, axis=1)
        return jnp.mean(pred != jnp.argmax(y, axis=1))


@dataclasses.dataclass(frozen=True)
class RidgeRegression:
    lam: float = 1e-5
    strongly_convex: bool = True
    name: str = "ridge"

    @property
    def hess_reg(self) -> float:
        return self.lam

    def value(self, w: jax.Array, data: Dataset) -> jax.Array:
        r = data.x @ w - data.y
        return 0.5 * jnp.mean(r * r) + 0.5 * self.lam * w @ w

    def gradient_via(self, w: jax.Array, data: Dataset,
                     mv: Optional[MatVec] = None) -> jax.Array:
        mv = mv or _plain_mv(data)
        n = data.x.shape[0]
        beta = mv("X", w) - data.y
        return mv("XT", beta) / n + self.lam * w

    def gradient(self, w: jax.Array, data: Dataset) -> jax.Array:
        return self.gradient_via(w, data)

    def hess_sqrt(self, w: jax.Array, data: Dataset) -> jax.Array:
        return data.x / jnp.sqrt(data.x.shape[0])

    def masked_value(self, w: jax.Array, data: Dataset,
                     row_ok: jax.Array) -> jax.Array:
        r = data.x @ w - data.y
        return 0.5 * (r * r * row_ok).sum() / jnp.maximum(row_ok.sum(), 1.0) \
            + 0.5 * self.lam * w @ w

    def error(self, w: jax.Array, x: jax.Array, y: jax.Array) -> jax.Array:
        r = x @ w - y
        return jnp.mean(r * r)


@dataclasses.dataclass(frozen=True)
class LinearProgramIPM:
    """One interior-point stage of  min c.x  s.t.  Ax <= b  (paper Sec. 4.3).

    data.x = A (n, m), data.y = b (n,); c and tau are parameters here.
    Strongly convex on the interior when A has full column rank.
    """
    c: jax.Array
    tau: float = 10.0
    strongly_convex: bool = True
    name: str = "lp_ipm"

    @property
    def hess_reg(self) -> float:
        return 0.0

    def value(self, w: jax.Array, data: Dataset) -> jax.Array:
        slack = data.y - data.x @ w
        barrier = jnp.where(slack > 0, jnp.log(jnp.maximum(slack, 1e-30)),
                            -jnp.inf)
        return self.tau * self.c @ w - barrier.sum()

    def gradient_via(self, w: jax.Array, data: Dataset,
                     mv: Optional[MatVec] = None) -> jax.Array:
        mv = mv or _plain_mv(data)
        alpha = mv("X", w)
        beta = 1.0 / (data.y - alpha)
        return self.tau * self.c + mv("XT", beta)

    def gradient(self, w: jax.Array, data: Dataset) -> jax.Array:
        return self.gradient_via(w, data)

    def hess_sqrt(self, w: jax.Array, data: Dataset) -> jax.Array:
        slack = data.y - data.x @ w
        return data.x / jnp.abs(slack)[:, None]


@dataclasses.dataclass(frozen=True)
class LassoDualIPM:
    """Interior-point stage of the Lasso dual (paper Sec. 4.3):
    min_z tau/2 ||y - z||^2 - sum_j log(lam - x_j.z) - sum_j log(lam + x_j.z).

    data.x: (n, d) measurement matrix (columns x_j are the dual constraints);
    data.y: (n,) measurements; optimizes over z in R^n.
    """
    lam: float = 1.0
    tau: float = 10.0
    strongly_convex: bool = True
    name: str = "lasso_dual_ipm"

    @property
    def hess_reg(self) -> float:
        return self.tau

    def value(self, z: jax.Array, data: Dataset) -> jax.Array:
        alpha = data.x.T @ z                                 # (d,)
        lo, hi = self.lam - alpha, self.lam + alpha
        ok = (lo > 0) & (hi > 0)
        bar = jnp.where(ok, jnp.log(jnp.maximum(lo, 1e-30))
                        + jnp.log(jnp.maximum(hi, 1e-30)), -jnp.inf)
        r = data.y - z
        return 0.5 * self.tau * r @ r - bar.sum()

    def gradient_via(self, z: jax.Array, data: Dataset,
                     mv: Optional[MatVec] = None) -> jax.Array:
        mv = mv or _plain_mv(data)
        alpha = mv("XT", z)
        beta = 1.0 / (self.lam - alpha)
        gamma = 1.0 / (self.lam + alpha)
        return self.tau * (z - data.y) + mv("X", beta - gamma)

    def gradient(self, z: jax.Array, data: Dataset) -> jax.Array:
        return self.gradient_via(z, data)

    def hess_sqrt(self, z: jax.Array, data: Dataset) -> jax.Array:
        """grad^2 f = tau I + X Lam X^T; A = sqrt(Lam) X^T  ((d, n))."""
        alpha = data.x.T @ z
        lam_diag = 1.0 / (self.lam - alpha) ** 2 + 1.0 / (self.lam + alpha) ** 2
        return jnp.sqrt(lam_diag)[:, None] * data.x.T
