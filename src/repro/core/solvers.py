"""Linear solvers for the Newton direction.

Strongly convex: Cholesky (small d at the master, paper Alg. 4 step 16) or CG
(paper footnote 6).  Weakly convex: eigendecomposition pseudo-inverse or MINRES
(paper Sec. 4.2 — "minimum-residual method").  All are jit-compatible.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp


def psd_solve(h: jax.Array, g: jax.Array, jitter: float = 1e-9) -> jax.Array:
    """Solve H p = g for symmetric PD H via Cholesky with a tiny jitter."""
    d = h.shape[0]
    chol = jnp.linalg.cholesky(h + jitter * jnp.eye(d, dtype=h.dtype))
    return jax.scipy.linalg.cho_solve((chol, True), g)


def psd_pinv_solve(h: jax.Array, g: jax.Array,
                   rtol: float = 1e-6) -> jax.Array:
    """Moore-Penrose solve H^+ g via symmetric eigendecomposition.

    Used for the weakly-convex Newton-MR update p = -H^+ grad (paper Eq. 3)
    when d is small enough to factorize at the master.
    """
    evals, evecs = jnp.linalg.eigh(h)
    cutoff = rtol * jnp.max(jnp.abs(evals))
    inv = jnp.where(jnp.abs(evals) > cutoff, 1.0 / evals, 0.0)
    return evecs @ (inv * (evecs.T @ g))


def conjugate_gradient(matvec: Callable[[jax.Array], jax.Array], b: jax.Array,
                       x0: jax.Array, iters: int = 50,
                       tol: float = 1e-10) -> jax.Array:
    """Plain CG for PD systems (matvec-only access)."""
    def body(carry, _):
        x, r, p, rs = carry
        hp = matvec(p)
        denom = p @ hp
        alpha = jnp.where(denom > 0, rs / jnp.maximum(denom, 1e-30), 0.0)
        x = x + alpha * p
        r = r - alpha * hp
        rs_new = r @ r
        beta = rs_new / jnp.maximum(rs, 1e-30)
        live = (rs_new > tol).astype(b.dtype)
        p = live * (r + beta * p)
        return (x, r, p, rs_new), None

    r0 = b - matvec(x0)
    (x, _, _, _), _ = jax.lax.scan(body, (x0, r0, r0, r0 @ r0), None,
                                   length=iters)
    return x


def minres(matvec: Callable[[jax.Array], jax.Array], b: jax.Array,
           iters: int = 50) -> jax.Array:
    """MINRES via an explicit re-orthogonalized Lanczos basis.

    Builds V ((iters+1), d) and the tridiagonal T ((iters+1), iters), solves
    the small least-squares min ||T y - beta1 e1||, returns V[:iters]^T y.
    Converges to the minimum-residual solution; for a consistent PSD system
    this matches H^+ b on range(H) — exactly the Newton-MR direction the
    paper needs for weakly-convex objectives.  O(iters * d) memory, which is
    fine for master-side solves, and bit-stable under jit.
    """
    d = b.shape[0]
    iters = min(iters, d)           # Krylov space cannot exceed dim(b)
    beta1 = jnp.linalg.norm(b)
    v1 = b / jnp.maximum(beta1, 1e-30)

    def body(carry, i):
        vs, alphas, betas, live = carry
        v_i = vs[i]
        hv = matvec(v_i)
        alpha = v_i @ hv
        hv = hv - alpha * v_i - betas[i] * vs[i - 1]
        # Full re-orthogonalization against the basis built so far.
        mask = (jnp.arange(iters + 1) <= i)[:, None].astype(b.dtype)
        proj = (vs * mask) @ hv
        hv = hv - (vs * mask).T @ proj
        beta = jnp.linalg.norm(hv)
        # Lanczos breakdown: the Krylov space is exhausted; zero everything
        # from here on so T stays well-posed for the small least-squares.
        live_next = live & (beta > 1e-6 * beta1)
        lf = live.astype(b.dtype)
        v_next = lf * live_next.astype(b.dtype) * hv / jnp.maximum(beta, 1e-30)
        vs = vs.at[i + 1].set(v_next)
        alphas = alphas.at[i].set(lf * alpha)
        betas = betas.at[i + 1].set(lf * live_next.astype(b.dtype) * beta)
        return (vs, alphas, betas, live_next), None

    vs0 = jnp.zeros((iters + 1, d), b.dtype).at[0].set(v1)
    (vs, alphas, betas, _), _ = jax.lax.scan(
        body, (vs0, jnp.zeros(iters, b.dtype), jnp.zeros(iters + 1, b.dtype),
               jnp.asarray(True)),
        jnp.arange(iters))

    idx = jnp.arange(iters)
    t = jnp.zeros((iters + 1, iters), b.dtype)
    t = t.at[idx, idx].set(alphas)
    t = t.at[idx + 1, idx].set(betas[1:iters + 1])
    t = t.at[idx[:-1], idx[1:]].set(betas[1:iters])
    rhs = jnp.zeros(iters + 1, b.dtype).at[0].set(beta1)
    y, *_ = jnp.linalg.lstsq(t, rhs, rcond=1e-6)
    return vs[:iters].T @ y
