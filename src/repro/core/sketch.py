"""OverSketch: straggler-resilient Count-Sketch based randomized matrix products.

The paper's Eq. (4) sketch is ``S = (1/sqrt(N)) [S_1, ..., S_{N+e}]`` where each
``S_i in R^{n x b}`` is an independent Count-Sketch.  The sketched Gram
``H_hat = A^T S S^T A = (1/N) sum_i (S_i^T A)^T (S_i^T A)`` tolerates up to
``e`` straggling blocks: any surviving subset of blocks gives an unbiased
estimate after rescaling by the survivor count (``E[S_i S_i^T] = I``).

We never materialize S.  A Count-Sketch block is two integer/sign vectors
``(h, sigma)``; ``S_i^T A`` is a signed segment-sum of A's rows into b buckets.
The TPU-native formulation (one-hot MXU matmul) lives in ``repro.kernels``;
this module is the distribution-agnostic reference path used by the optimizer
and the kernels' oracle.
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Callable, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OverSketchConfig:
    """Configuration for the OverSketch sketch of Eq. (4).

    Attributes:
      sketch_dim: target sketch dimension m = N*b (excluding over-provision).
      block_size: b, the width of each Count-Sketch block (worker tile size).
      straggler_tolerance: zeta; e = ceil(zeta * N) extra blocks are added.
    """

    sketch_dim: int
    block_size: int
    straggler_tolerance: float = 0.25

    def __post_init__(self):
        if self.sketch_dim % self.block_size != 0:
            raise ValueError(
                f"sketch_dim {self.sketch_dim} must be divisible by "
                f"block_size {self.block_size}")

    @property
    def num_blocks(self) -> int:
        """N = m / b."""
        return self.sketch_dim // self.block_size

    @property
    def num_redundant(self) -> int:
        """e = ceil(zeta * N) over-provisioned blocks."""
        return int(math.ceil(self.straggler_tolerance * self.num_blocks))

    @property
    def total_blocks(self) -> int:
        return self.num_blocks + self.num_redundant

    @property
    def total_dim(self) -> int:
        return self.total_blocks * self.block_size


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class CountSketch:
    """(N+e) independent Count-Sketch blocks over n rows.

    h:     int32 (total_blocks, n)  bucket index in [0, b) per row per block.
    sigma: float (total_blocks, n)  Rademacher signs.
    block_size: static b.
    """

    h: jax.Array
    sigma: jax.Array
    block_size: int

    def tree_flatten(self):
        return (self.h, self.sigma), self.block_size

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], children[1], aux)

    @property
    def total_blocks(self) -> int:
        return self.h.shape[0]

    @property
    def num_rows(self) -> int:
        return self.h.shape[1]


def sample_countsketch(key: jax.Array, num_rows: int,
                       cfg: OverSketchConfig) -> CountSketch:
    """Draw an independent realization of the Eq. (4) sketch (fresh per iter)."""
    kh, ks = jax.random.split(key)
    h = jax.random.randint(kh, (cfg.total_blocks, num_rows), 0, cfg.block_size,
                           dtype=jnp.int32)
    sigma = jax.random.rademacher(
        ks, (cfg.total_blocks, num_rows), dtype=jnp.float32)
    return CountSketch(h=h, sigma=sigma, block_size=cfg.block_size)


def apply_block(h: jax.Array, sigma: jax.Array, block_size: int,
                a: jax.Array) -> jax.Array:
    """S_i^T A for one Count-Sketch block: (n,) x (n,) x (n, d) -> (b, d)."""
    signed = a * sigma[:, None].astype(a.dtype)
    return jax.ops.segment_sum(signed, h, num_segments=block_size)


def apply_sketch(cs: CountSketch, a: jax.Array) -> jax.Array:
    """All blocks: A (n, d) -> A_tilde (total_blocks, b, d).  Unscaled.

    The 1/sqrt(N) scale of Eq. (4) is folded into the Gram rescale (we divide
    by the survivor count there), which is what makes dropping blocks exact.
    """
    return jax.vmap(
        lambda h, s: apply_block(h, s, cs.block_size, a))(cs.h, cs.sigma)


def apply_sketch_chunked(cs: CountSketch, a_fn: Callable[[int], jax.Array],
                         num_chunks: int, chunk_rows: int,
                         d: int) -> jax.Array:
    """Streaming S^T A for tall A that should not be materialized.

    ``a_fn(c)`` returns chunk c of A with ``chunk_rows`` rows.  Row j of chunk
    c corresponds to global row ``c*chunk_rows + j`` of A (and of the sketch).
    """
    def body(c, acc):
        rows = a_fn(c)
        start = c * chunk_rows
        h_c = jax.lax.dynamic_slice_in_dim(cs.h, start, chunk_rows, axis=1)
        s_c = jax.lax.dynamic_slice_in_dim(cs.sigma, start, chunk_rows, axis=1)
        part = jax.vmap(
            lambda h, s: apply_block(h, s, cs.block_size, rows))(h_c, s_c)
        return acc + part

    init = jnp.zeros((cs.total_blocks, cs.block_size, d), dtype=jnp.float32)
    return jax.lax.fori_loop(0, num_chunks, body, init)


def sketched_gram(a_tilde: jax.Array,
                  survivors: Optional[jax.Array] = None, *,
                  use_kernels: bool = False) -> jax.Array:
    """H_hat = (1/N_avail) sum_{i in survivors} A_tilde_i^T A_tilde_i.

    a_tilde:   (total_blocks, b, d) sketched square root blocks.
    survivors: bool (total_blocks,) mask of non-straggling blocks; None = all.

    Dropping a block and rescaling keeps the estimator unbiased — this is the
    paper's "over"-sketching straggler resiliency, done as a masked reduction.
    ``use_kernels`` routes the reduction through the Pallas masked-Gram
    kernel (MXU tiles, straggler mask applied inside the accumulation).
    """
    if survivors is None:
        survivors = jnp.ones((a_tilde.shape[0],), dtype=bool)
    if use_kernels:
        from repro.kernels import ops as kops
        return kops.oversketch_gram(a_tilde, survivors)
    m = survivors.astype(a_tilde.dtype)
    n_avail = jnp.maximum(m.sum(), 1.0)
    grams = jnp.einsum("kbd,kbe->kde", a_tilde, a_tilde)
    return jnp.einsum("k,kde->de", m, grams) / n_avail


def oversketched_gram(key: jax.Array, a: jax.Array, cfg: OverSketchConfig,
                      survivors: Optional[jax.Array] = None, *,
                      use_kernels: bool = False) -> jax.Array:
    """One-shot H_hat ~= A^T A with straggler resiliency (single device).

    ``use_kernels`` takes the fused streaming pipeline
    (``kernels.sketch_gram``): row-panels of A are sketched block-locally
    and the masked Gram accumulates in VMEM — A_tilde never hits HBM.
    The kernel's output grid is d-tiled, so the fused path runs for every
    d (``pick_d_tile`` sizes the resident tile to the VMEM budget).
    """
    cs = sample_countsketch(key, a.shape[0], cfg)
    if use_kernels:
        from repro.kernels import ops as kops
        if survivors is None:
            survivors = jnp.ones((cs.total_blocks,), dtype=bool)
        return kops.sketch_gram_count(cs.h, cs.sigma, a,
                                      cfg.block_size, survivors)
    return sketched_gram(apply_sketch(cs, a), survivors)


# ---------------------------------------------------------------------------
# Distributed (shard_map) path: sketch blocks spread over a mesh axis.
# ---------------------------------------------------------------------------

def distributed_sketched_gram(a: jax.Array, cs: CountSketch,
                              survivors: jax.Array, *,
                              mesh: jax.sharding.Mesh,
                              block_axis: str) -> jax.Array:
    """H_hat over a mesh: each ``block_axis`` shard owns total_blocks/axis
    sketch blocks, computes its local masked Gram contribution, and the
    result is a straggler-masked all-reduce (`resilient psum`).

    a is replicated (or row-sharded and pre-reduced by the caller); h/sigma/
    survivors are sharded on their leading block dimension.
    """
    from jax.sharding import PartitionSpec as P

    def local(a_l, h_l, s_l, m_l):
        a_t = jax.vmap(
            lambda h, s: apply_block(h, s, cs.block_size, a_l))(h_l, s_l)
        mf = m_l.astype(a_t.dtype)
        gram = jnp.einsum("k,kbd,kbe->de", mf, a_t, a_t)
        n_local = mf.sum()
        gram = jax.lax.psum(gram, block_axis)
        n_avail = jax.lax.psum(n_local, block_axis)
        return gram / jnp.maximum(n_avail, 1.0)

    spec_blocks = P(block_axis)
    return jax.shard_map(
        local, mesh=mesh,
        in_specs=(P(), spec_blocks, spec_blocks, spec_blocks),
        out_specs=P())(a, cs.h, cs.sigma, survivors)
