"""OverSketched Newton core: sketching, coded computation, the Newton loop."""
from repro.core.sketch import (OverSketchConfig, CountSketch,
                               sample_countsketch, apply_sketch,
                               sketched_gram, oversketched_gram)
from repro.core.coded import (ProductCode, make_code, encode_2d, coded_matvec,
                              detect_corrupted, peel_decode, verified_decode)
from repro.core.straggler import StragglerModel, SimClock
from repro.core.objectives import (Dataset, LogisticRegression,
                                   SoftmaxRegression, RidgeRegression,
                                   LinearProgramIPM, LassoDualIPM)
from repro.core.newton import NewtonConfig, NewtonResult, oversketched_newton
