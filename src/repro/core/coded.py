"""Coded matrix-vector multiplication with a 2-D product code (paper Alg. 1).

The data matrix's row-blocks are laid out on a g x g grid and extended with a
parity column (row sums), a parity row (column sums) and a corner (total sum),
giving (g+1)^2 worker tasks for T = g^2 systematic blocks.  Every row and
column of the extended grid satisfies a single-parity-check constraint, so a
*peeling decoder* recovers any erasure pattern with at most one missing cell
per row xor column per round (and most patterns with up to 2g+1 erasures).

Encoding happens once (the paper amortizes it across iterations since the data
matrix is fixed); decode is a cheap `lax.fori_loop` of vectorized peel rounds.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ProductCode:
    """Static geometry of the 2-D product code."""

    num_blocks: int   # T systematic row blocks (pre-padding)
    block_rows: int   # b rows per block
    grid: int         # g, where g*g >= T

    @property
    def num_workers(self) -> int:
        return (self.grid + 1) ** 2

    @property
    def padded_blocks(self) -> int:
        return self.grid * self.grid


def make_code(num_rows: int, block_rows: int) -> ProductCode:
    t = -(-num_rows // block_rows)
    g = int(math.ceil(math.sqrt(t)))
    return ProductCode(num_blocks=t, block_rows=block_rows, grid=g)


def encode_2d(a: jax.Array, code: ProductCode) -> jax.Array:
    """A (rows, s) -> encoded blocks ((g+1), (g+1), b, s).

    Row padding with zeros up to g^2 * b rows; parities are sums of blocks.
    """
    g, b = code.grid, code.block_rows
    rows, s = a.shape
    pad = code.padded_blocks * b - rows
    a_pad = jnp.pad(a, ((0, pad), (0, 0)))
    blocks = a_pad.reshape(g, g, b, s)
    row_par = blocks.sum(axis=1, keepdims=True)            # (g, 1, b, s)
    top = jnp.concatenate([blocks, row_par], axis=1)       # (g, g+1, b, s)
    col_par = top.sum(axis=0, keepdims=True)               # (1, g+1, b, s)
    return jnp.concatenate([top, col_par], axis=0)         # (g+1, g+1, b, s)


def coded_block_products(enc: jax.Array, x: jax.Array) -> jax.Array:
    """Every worker's task: its block times x.  ((g+1),(g+1),b,s) -> (...,b)."""
    return jnp.einsum("rcbs,s->rcb", enc, x)


def _peel_axis(vals: jax.Array, known: jax.Array, axis: int) -> Tuple[jax.Array, jax.Array]:
    """One peel round along rows (axis=0 constraints iterate over columns) or
    columns.  Constraint per line: sum(systematic) - parity_cell = 0."""
    n = vals.shape[0]  # (g+1, g+1, b), square
    sgn = jnp.where(jnp.arange(n) == n - 1, -1.0, 1.0)
    if axis == 0:   # row constraints: sum over c of sgn[c] * v[r, c] = 0
        sgn_rc = sgn[None, :]
        reduce_axis = 1
    else:           # column constraints: sum over r of sgn[r] * v[r, c] = 0
        sgn_rc = sgn[:, None]
        reduce_axis = 0
    kf = known.astype(vals.dtype)
    line_sum = (vals * (sgn_rc * kf)[..., None]).sum(axis=reduce_axis,
                                                     keepdims=True)
    missing = (~known).sum(axis=reduce_axis, keepdims=True)
    recover_line = missing == 1
    candidate = -line_sum * sgn_rc[..., None]
    rec_mask = recover_line & (~known)
    vals = jnp.where(rec_mask[..., None], candidate, vals)
    known = known | rec_mask
    return vals, known


def peel_decode(products: jax.Array, known: jax.Array,
                code: ProductCode) -> Tuple[jax.Array, jax.Array]:
    """Peeling decoder.  products ((g+1),(g+1),b) with erased cells arbitrary,
    known ((g+1),(g+1)) bool.  Returns (systematic blocks (g,g,b), success)."""
    vals = jnp.where(known[..., None], products, 0.0)

    def round_fn(_, carry):
        v, k = carry
        v, k = _peel_axis(v, k, axis=0)
        v, k = _peel_axis(v, k, axis=1)
        return v, k

    vals, known = jax.lax.fori_loop(0, code.grid + 1, round_fn, (vals, known))
    g = code.grid
    success = known[:g, :g].all()
    return vals[:g, :g], success


def decode_matvec(products: jax.Array, known: jax.Array, code: ProductCode,
                  out_rows: int) -> Tuple[jax.Array, jax.Array]:
    """Full decode back to y = A @ x of length out_rows."""
    sys_blocks, ok = peel_decode(products, known, code)
    y = sys_blocks.reshape(code.padded_blocks * code.block_rows)
    return y[:out_rows], ok


def detect_corrupted(products: jax.Array, known: jax.Array,
                     code: ProductCode, rtol: float = 1e-3) -> jax.Array:
    """Parity-check detection of corrupted (not merely missing) products.

    The same single-parity-check constraints the peeling decoder uses for
    erasures double as integrity checks: a *corrupted* known cell violates
    both its row and its column constraint, while an erased cell merely
    makes its two lines uncheckable (a constraint needs every cell of the
    line).  A known cell is flagged when at least one of its checks fires
    and the other fires or is uncheckable — exact for a single corrupted
    cell in a fully-known grid, conservative when corruption shares lines
    with erasures (over-flagging demotes innocents to erasures; an
    undecodable pattern then falls through to the master's billed full
    relaunch, never to a silently wrong result).

    Returns a ((g+1), (g+1)) bool grid of cells to demote to erasures,
    feeding the existing ``peel_decode`` path unchanged.
    """
    from repro.kernels.coded_matvec import parity_residuals  # lazy: layering
    del code  # geometry is carried by the grid shape itself
    row_res, row_mag, col_res, col_mag = parity_residuals(products, known)
    full_rows = known.all(axis=1)
    full_cols = known.all(axis=0)
    tiny = jnp.finfo(jnp.float32).tiny
    rows_bad = full_rows & (row_res > rtol * (row_mag + tiny))
    cols_bad = full_cols & (col_res > rtol * (col_mag + tiny))
    flagged = ((rows_bad[:, None] & cols_bad[None, :])
               | (rows_bad[:, None] & ~full_cols[None, :])
               | (cols_bad[None, :] & ~full_rows[:, None]))
    return known & flagged


def verified_decode(products: jax.Array, arrived: jax.Array,
                    code: ProductCode, out_rows: int, rtol: float = 1e-3
                    ) -> Tuple[Optional[jax.Array], bool, int]:
    """Corruption-tolerant decode: detect, erase, peel, then verify.

    1. ``detect_corrupted`` localizes corrupted cells with at least one
       checkable line and demotes them to erasures.
    2. The peeling decoder runs on the surviving cells (undecodable
       pattern => give up).
    3. Verification: the decoded systematic blocks extend to a *unique*
       codeword grid (parities are exact sums of block products — the
       products are linear in the blocks); any surviving arrived cell
       that disagrees with that extension witnesses corruption the
       detector could not localize, so the decode is rejected rather
       than silently wrong.

    Returns ``(y, ok, flagged)``: the decoded matvec (None when
    rejected), whether it is trustworthy, and how many cells the
    detector demoted.  The one blind spot is fundamental, not a decoder
    weakness: a corrupted systematic cell whose three witnesses (its row
    parity, its column parity, the corner) are all erased leaves the
    arrived data exactly consistent with a valid codeword carrying the
    corrupted value — no decoder can tell the difference.  Callers
    relaunch on ``ok=False`` (the paper's straggler fallback, reused).
    """
    flagged = detect_corrupted(products, arrived, code, rtol)
    n_flagged = int(jnp.sum(flagged))
    known = arrived & ~flagged
    sys_blocks, ok = peel_decode(products, known, code)
    if not bool(ok):
        return None, False, n_flagged
    # Unique codeword extension of the decoded systematic part.
    row_par = sys_blocks.sum(axis=1, keepdims=True)
    top = jnp.concatenate([sys_blocks, row_par], axis=1)
    col_par = top.sum(axis=0, keepdims=True)
    full = jnp.concatenate([top, col_par], axis=0)     # (g+1, g+1, b)
    resid = jnp.linalg.norm(full - products, axis=-1)
    mag = jnp.linalg.norm(full, axis=-1) + jnp.finfo(jnp.float32).tiny
    mismatch = known & (resid > rtol * mag)
    if bool(mismatch.any()):
        return None, False, n_flagged
    y = sys_blocks.reshape(code.padded_blocks * code.block_rows)
    return y[:out_rows], True, n_flagged


def coded_matvec(enc: jax.Array, x: jax.Array, code: ProductCode,
                 out_rows: int,
                 erased: Optional[jax.Array] = None) -> Tuple[jax.Array, jax.Array]:
    """End-to-end straggler-resilient matvec given pre-encoded blocks.

    erased: bool ((g+1),(g+1)) straggler mask (True = missing).  None = none.
    """
    prods = coded_block_products(enc, x)
    if erased is None:
        known = jnp.ones(prods.shape[:2], dtype=bool)
    else:
        known = ~erased
    return decode_matvec(prods, known, code, out_rows)


# ---------------------------------------------------------------------------
# Distributed (shard_map) path: one coded block per device slot.
# ---------------------------------------------------------------------------

def distributed_coded_matvec(enc_flat: jax.Array, x: jax.Array,
                             erased_flat: jax.Array, code: ProductCode,
                             out_rows: int, *, mesh: jax.sharding.Mesh,
                             worker_axis: str) -> Tuple[jax.Array, jax.Array]:
    """Coded matvec with worker tasks sharded over ``worker_axis``.

    enc_flat: (W_pad, b, s) encoded blocks flattened row-major and zero-padded
       to a multiple of the axis size (W_pad >= (g+1)^2).
    erased_flat: (W_pad,) straggler erasures.  Erased workers' products are
       masked before the gather — simulating "the master never saw them".
    """
    from jax.sharding import PartitionSpec as P

    def local(enc_l, x_l, er_l):
        prod = jnp.einsum("wbs,s->wb", enc_l, x_l)
        prod = jnp.where(er_l[:, None], 0.0, prod)
        return jax.lax.all_gather(prod, worker_axis, tiled=True)

    prods_flat = jax.shard_map(
        local, mesh=mesh,
        in_specs=(P(worker_axis), P(), P(worker_axis)),
        out_specs=P(), check_vma=False)(enc_flat, x, erased_flat)
    w = code.num_workers
    g1 = code.grid + 1
    prods = prods_flat[:w].reshape(g1, g1, code.block_rows)
    known = (~erased_flat[:w]).reshape(g1, g1)
    return decode_matvec(prods, known, code, out_rows)
