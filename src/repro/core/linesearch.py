"""Distributed line search (paper Sec. 3.2).

The master broadcasts the descent direction p_t; workers evaluate their local
partial objective at every candidate step in S = {4^0, 4^-1, ..., 4^-5}; the
master sums partials and picks the largest alpha satisfying the Armijo
condition (Eq. 5), or — for the weakly-convex Newton-MR path — the gradient
norm condition (Eq. 6).  One extra communication round per iteration.
"""
from __future__ import annotations

from typing import Callable, Tuple

import jax
import jax.numpy as jnp

DEFAULT_CANDIDATES = tuple(4.0 ** (-i) for i in range(6))   # 1, 1/4, ..., 4^-5


def armijo_select(f_trials: jax.Array, f0: jax.Array, gtp: jax.Array,
                  candidates: jax.Array, beta: float = 0.1) -> jax.Array:
    """Largest alpha in the candidate set with
    f(w + a p) <= f(w) + a * beta * p.g   (Eq. 5).  Falls back to the
    smallest candidate if none qualifies (gtp < 0 ensures progress)."""
    ok = f_trials <= f0 + candidates * beta * gtp
    ok = ok & jnp.isfinite(f_trials)
    # candidates are sorted descending; pick the first qualifying one.
    idx = jnp.argmax(ok)
    any_ok = ok.any()
    return jnp.where(any_ok, candidates[idx], candidates[-1])


def gradnorm_select(gnorm2_trials: jax.Array, gnorm2_0: jax.Array,
                    ptHg: jax.Array, candidates: jax.Array,
                    beta: float = 0.1) -> jax.Array:
    """Largest alpha with ||g(w + a p)||^2 <= ||g||^2 + 2 a beta p^T H_hat g
    (Eq. 6, weakly-convex Newton-MR line search)."""
    ok = gnorm2_trials <= gnorm2_0 + 2.0 * candidates * beta * ptHg
    ok = ok & jnp.isfinite(gnorm2_trials)
    idx = jnp.argmax(ok)
    any_ok = ok.any()
    return jnp.where(any_ok, candidates[idx], candidates[-1])


def linesearch_strongly_convex(objective, data, w: jax.Array, p: jax.Array,
                               g: jax.Array, beta: float = 0.1,
                               candidates: Tuple[float, ...] = DEFAULT_CANDIDATES
                               ) -> jax.Array:
    cand = jnp.asarray(candidates)
    f0 = objective.value(w, data)
    f_trials = jax.vmap(lambda a: objective.value(w + a * p, data))(cand)
    return armijo_select(f_trials, f0, p @ g, cand, beta)


def linesearch_weakly_convex(objective, data, w: jax.Array, p: jax.Array,
                             g: jax.Array, h_hat_g: jax.Array,
                             beta: float = 0.1,
                             candidates: Tuple[float, ...] = DEFAULT_CANDIDATES
                             ) -> jax.Array:
    """Workers compute grad f_i at trial points; master uses ||grad f||^2
    (paper footnote 4) and the sketched Hessian in the Armijo RHS."""
    cand = jnp.asarray(candidates)
    g0 = g @ g
    def gn2(a):
        gt = objective.gradient(w + a * p, data)
        return gt @ gt
    gnorm2_trials = jax.vmap(gn2)(cand)
    return gradnorm_select(gnorm2_trials, g0, p @ h_hat_g, cand, beta)


def distributed_f_trials(objective, data_local, w: jax.Array, p: jax.Array,
                         candidates: jax.Array, axis: str) -> jax.Array:
    """Inside shard_map: per-shard partial objective values at trial points,
    psum-reduced over ``axis``.  The objective must decompose as a mean over
    samples plus a (replicated) regularizer; we weight partials by shard size
    and divide by the global count after the reduction."""
    n_local = data_local.x.shape[0]

    def f_partial(a):
        # Unregularized partial sum; regularizer is added by the caller.
        wa = w + a * p
        return objective.value(wa, data_local) * n_local

    trials = jax.vmap(f_partial)(candidates)
    total = jax.lax.psum(trials, axis)
    n = jax.lax.psum(jnp.asarray(n_local, jnp.float32), axis)
    return total / n
