"""Straggler model + simulation clock.

Calibrated to the paper's Fig. 1 (3600 AWS Lambda workers): median job time
~135 s with ~2% of workers straggling up to ~180 s (~1.33x median).  We model
per-worker job time as

    t_w = base * lognormal(0, body_sigma) * (1 + straggler * tail)

with P[straggler] = p_tail and tail ~ U[tail_lo, tail_hi].  The *clock*
(``SimClock``, a facade over the discrete-event ``repro.runtime`` fleet
engine) turns per-phase worker lifecycles into simulated wall time and
dollars under pluggable termination policies (wait_all / k_of_n /
speculative / hedged / coded_decode), which is how every optimizer in this
repo is scored — the container has one physical device, so comparisons the
paper makes in wall-clock and AWS dollars on Lambda are made here in
deterministic simulated seconds and simulated dollars.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class StragglerModel:
    base_time: float = 1.0        # median per-worker job time (per work unit)
    body_sigma: float = 0.08      # lognormal body spread
    p_tail: float = 0.02          # Fig. 1: ~2% stragglers
    tail_lo: float = 0.3          # straggler slowdown factor lower bound
    tail_hi: float = 1.5          # up to 2.5x median
    invoke_overhead: float = 0.1  # per-phase worker invocation overhead
    comm_per_unit: float = 0.05   # storage/communication cost per data unit
    flops_per_second: float = 2e6  # simulated worker throughput (Lambda-ish
    #                               scale at the CPU bench problem sizes)

    def sample_times(self, key: jax.Array, num_workers: int,
                     work_per_worker: float = 1.0,
                     flops_per_worker: Optional[float] = None) -> jax.Array:
        """Per-worker job completion times for one distributed phase.

        Work is given either in abstract seconds (work_per_worker) or as a
        per-worker flop count (flops_per_worker), converted through the
        model's simulated throughput — phases with genuinely different
        per-worker compute (a matvec block vs a local Newton solve) then get
        proportionally different durations, which is what makes the
        scheme-vs-scheme comparisons honest."""
        if flops_per_worker is not None:
            work_per_worker = flops_per_worker / self.flops_per_second
        k1, k2, k3 = jax.random.split(key, 3)
        body = jnp.exp(self.body_sigma * jax.random.normal(k1, (num_workers,)))
        is_tail = jax.random.bernoulli(k2, self.p_tail, (num_workers,))
        tail = jax.random.uniform(k3, (num_workers,), minval=self.tail_lo,
                                  maxval=self.tail_hi)
        slow = 1.0 + is_tail * tail
        return self.invoke_overhead + self.base_time * work_per_worker * body * slow


# The production termination policies live in the ``repro.runtime.policies``
# registry (what SimClock.phase dispatches through); the helpers below are
# the jax-native order-statistic forms kept for direct use on sampled time
# arrays (tests, notebooks).  ``speculative_time`` — the only nontrivial one
# — delegates to the registry so there is a single implementation.

def wait_all_time(times: jax.Array) -> jax.Array:
    """Policy: wait for every worker (uncoded baseline)."""
    return jnp.max(times)


def k_of_n_time(times: jax.Array, k: int) -> jax.Array:
    """Policy: proceed when any k of n workers finish (coded / sketched)."""
    return jnp.sort(times)[k - 1]


def k_of_n_mask(times: jax.Array, k: int) -> jax.Array:
    """Which workers finished by the k-of-n deadline (ties kept, >=k true)."""
    return times <= k_of_n_time(times, k)


def speculative_time(times: jax.Array, key: jax.Array,
                     model: StragglerModel,
                     watch_fraction: float = 0.9,
                     work_per_worker: float = 1.0,
                     flops_per_worker: Optional[float] = None) -> jax.Array:
    """Policy: speculative execution (paper Sec. 5.3).

    Wait for ``watch_fraction`` of workers, then re-launch the stragglers and
    take min(original finish, deadline + relaunch finish) per straggler.
    Relaunches redo the phase's *actual* work (``work_per_worker`` /
    ``flops_per_worker`` must match what produced ``times``) — the historical
    default of unit work made relaunched stragglers finish unrealistically
    fast, flattering every speculative baseline.
    """
    from repro.runtime import policies as rt_policies   # lazy: imports us
    import numpy as np
    n = times.shape[0]
    ctx = rt_policies.PhaseContext(
        watch_fraction=watch_fraction,
        sample_relaunch=lambda: np.asarray(
            model.sample_times(key, n, work_per_worker, flops_per_worker),
            dtype=np.float64))
    out = rt_policies.get_policy("speculative")(
        np.asarray(times, dtype=np.float64), ctx)
    return jnp.asarray(out.elapsed)


class SimClock:
    """Simulated wall time (and dollars) across distributed phases.

    Thin facade over ``repro.runtime.FleetEngine`` — the discrete-event
    fleet simulator with per-worker lifecycle (cold start / failure-retry),
    the termination-policy registry, cost accounting, and trace
    record/replay.  The historical ``phase()``/``charge()``/``time`` API is
    preserved so optimizer call sites are unchanged; richer behaviour is
    opted into via the keyword-only constructor args (see
    ``runtime/README.md``).
    """

    def __init__(self, model: StragglerModel, time: float = 0.0, *,
                 fleet=None, cost=None, recorder=None, replay=None,
                 pool=None, telemetry=None, faults=None):
        from repro.runtime import FleetEngine   # lazy: runtime imports us
        self.engine = FleetEngine(model, fleet=fleet, cost=cost,
                                  recorder=recorder, replay=replay,
                                  pool=pool, telemetry=telemetry,
                                  faults=faults)
        if time:
            self.engine.seconds += float(time)

    @property
    def model(self) -> StragglerModel:
        return self.engine.model

    @property
    def time(self) -> float:
        return self.engine.seconds

    @property
    def dollars(self) -> float:
        return self.engine.dollars

    @property
    def ledger(self):
        return self.engine.ledger

    @property
    def telemetry(self):
        """The attached ``obs.Telemetry`` (or the zero-overhead no-op)."""
        return self.engine.telemetry

    @property
    def last_corruption(self):
        """Boolean per-worker corruption flags of the most recent phase
        (None unless a fault plan with a ``CorruptionSpec`` is attached) —
        the coded-matvec layer turns these into parity-detected erasures."""
        return self.engine.last_corruption

    def charge(self, elapsed: float, phase_name=None) -> None:
        """Directly add externally-computed phase time (e.g. the coded
        master's wait-until-decodable simulation)."""
        self.engine.charge(elapsed, phase_name=phase_name)

    def phase(self, key: jax.Array, num_workers: int, *,
              work_per_worker: float = 1.0,
              flops_per_worker: Optional[float] = None,
              policy: str = "wait_all", k: Optional[int] = None,
              comm_units: float = 0.0,
              decodable=None,
              not_before: Optional[float] = None,
              memory_gb: Optional[float] = None,
              working_set_gb: Optional[float] = None,
              phase_name: Optional[str] = None,
              phase_deps: Tuple[str, ...] = ()) -> Tuple[float, jax.Array]:
        """Simulate one phase; returns (elapsed, finished_mask).

        ``not_before`` (absolute simulated seconds) overlaps this phase
        with whatever advanced the clock since that time; ``memory_gb``
        bills it at its own Lambda size; ``working_set_gb`` declares the
        true per-worker working set (the fault plane's OOM threshold);
        ``phase_name``/``phase_deps`` label the phase's telemetry span —
        see ``FleetEngine.run_phase``."""
        elapsed, mask = self.engine.run_phase(
            key, num_workers, work_per_worker=work_per_worker,
            flops_per_worker=flops_per_worker, policy=policy, k=k,
            comm_units=comm_units, decodable=decodable,
            not_before=not_before, memory_gb=memory_gb,
            working_set_gb=working_set_gb,
            phase_name=phase_name, phase_deps=phase_deps)
        return elapsed, jnp.asarray(mask)
