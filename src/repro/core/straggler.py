"""Straggler model + simulation clock.

Calibrated to the paper's Fig. 1 (3600 AWS Lambda workers): median job time
~135 s with ~2% of workers straggling up to ~180 s (~1.33x median).  We model
per-worker job time as

    t_w = base * lognormal(0, body_sigma) * (1 + straggler * tail)

with P[straggler] = p_tail and tail ~ U[tail_lo, tail_hi].  The *clock* turns
per-phase worker-time samples into simulated wall time under different
termination policies (wait-all / k-of-n / speculative re-execution), which is
how every optimizer in this repo is scored — the container has one physical
device, so comparisons that the paper makes in wall-clock on Lambda are made
here in deterministic simulated seconds.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class StragglerModel:
    base_time: float = 1.0        # median per-worker job time (per work unit)
    body_sigma: float = 0.08      # lognormal body spread
    p_tail: float = 0.02          # Fig. 1: ~2% stragglers
    tail_lo: float = 0.3          # straggler slowdown factor lower bound
    tail_hi: float = 1.5          # up to 2.5x median
    invoke_overhead: float = 0.1  # per-phase worker invocation overhead
    comm_per_unit: float = 0.05   # storage/communication cost per data unit
    flops_per_second: float = 2e6  # simulated worker throughput (Lambda-ish
    #                               scale at the CPU bench problem sizes)

    def sample_times(self, key: jax.Array, num_workers: int,
                     work_per_worker: float = 1.0,
                     flops_per_worker: Optional[float] = None) -> jax.Array:
        """Per-worker job completion times for one distributed phase.

        Work is given either in abstract seconds (work_per_worker) or as a
        per-worker flop count (flops_per_worker), converted through the
        model's simulated throughput — phases with genuinely different
        per-worker compute (a matvec block vs a local Newton solve) then get
        proportionally different durations, which is what makes the
        scheme-vs-scheme comparisons honest."""
        if flops_per_worker is not None:
            work_per_worker = flops_per_worker / self.flops_per_second
        k1, k2, k3 = jax.random.split(key, 3)
        body = jnp.exp(self.body_sigma * jax.random.normal(k1, (num_workers,)))
        is_tail = jax.random.bernoulli(k2, self.p_tail, (num_workers,))
        tail = jax.random.uniform(k3, (num_workers,), minval=self.tail_lo,
                                  maxval=self.tail_hi)
        slow = 1.0 + is_tail * tail
        return self.invoke_overhead + self.base_time * work_per_worker * body * slow


def wait_all_time(times: jax.Array) -> jax.Array:
    """Policy: wait for every worker (uncoded baseline)."""
    return jnp.max(times)


def k_of_n_time(times: jax.Array, k: int) -> jax.Array:
    """Policy: proceed when any k of n workers finish (coded / sketched)."""
    return jnp.sort(times)[k - 1]


def k_of_n_mask(times: jax.Array, k: int) -> jax.Array:
    """Which workers finished by the k-of-n deadline (ties kept, >=k true)."""
    return times <= k_of_n_time(times, k)


def speculative_time(times: jax.Array, key: jax.Array,
                     model: StragglerModel,
                     watch_fraction: float = 0.9) -> jax.Array:
    """Policy: speculative execution (paper Sec. 5.3).

    Wait for ``watch_fraction`` of workers, then re-launch the stragglers and
    take min(original finish, deadline + relaunch finish) per straggler.
    """
    n = times.shape[0]
    k = jnp.maximum(1, jnp.floor(watch_fraction * n).astype(jnp.int32))
    deadline = jnp.sort(times)[k - 1]
    relaunch = model.sample_times(key, n)
    effective = jnp.where(times <= deadline, times,
                          jnp.minimum(times, deadline + relaunch))
    return jnp.max(effective)


@dataclasses.dataclass
class SimClock:
    """Accumulates simulated wall time across distributed phases."""

    model: StragglerModel
    time: float = 0.0

    def charge(self, elapsed: float) -> None:
        """Directly add externally-computed phase time (e.g. the coded
        master's wait-until-decodable simulation)."""
        self.time = self.time + float(elapsed)

    def phase(self, key: jax.Array, num_workers: int, *,
              work_per_worker: float = 1.0,
              flops_per_worker: Optional[float] = None,
              policy: str = "wait_all", k: Optional[int] = None,
              comm_units: float = 0.0) -> Tuple[jax.Array, jax.Array]:
        """Simulate one phase; returns (elapsed, finished_mask)."""
        ktime, kspec = jax.random.split(key)
        times = self.model.sample_times(ktime, num_workers, work_per_worker,
                                        flops_per_worker)
        if policy == "wait_all":
            elapsed = wait_all_time(times)
            mask = jnp.ones((num_workers,), dtype=bool)
        elif policy == "k_of_n":
            assert k is not None
            elapsed = k_of_n_time(times, k)
            mask = k_of_n_mask(times, k)
        elif policy == "speculative":
            elapsed = speculative_time(times, kspec, self.model)
            mask = jnp.ones((num_workers,), dtype=bool)
        else:
            raise ValueError(f"unknown policy {policy}")
        elapsed = elapsed + self.model.comm_per_unit * comm_units
        self.time = self.time + float(elapsed)
        return elapsed, mask
